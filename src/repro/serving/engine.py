"""Live serving engine: continuous-batching decode over persistent slots,
driven by an EPARA ParallelPlan.

``ServiceRuntime`` owns one service's params and its DP replica groups.
The default ``mode="continuous"`` keeps a persistent in-flight batch of
decode slots per group; each ``step()``:

  (a) **evicts** slots whose request hit EOS or its own ``max_new_tokens``,
  (b) **admits** queued requests from the BS/MF composer into the freed
      slots (``compose(limit=free)``),
  (b2) advances **chunked prefill**: in-progress prompts are split into
      fixed bucket-sized chunks written straight through the arena's block
      tables, at most ``prefill_chunk`` tokens per group per step — so a
      long prompt never stalls live decode slots for more than one chunk
      (head-of-line isolation), and prefill compiles once per chunk
      BUCKET instead of once per prompt length,

with a **radix prefix cache** (``serving/prefix_cache.py``) in front of
(b): admissions of prefix-cacheable families look up the longest cached
prompt prefix and stitch its blocks into the new slot's table
(``arena.alloc(shared=...)``) so (b2) starts after the hit boundary;
divergence inside a shared block copies-on-write, retention follows the
task category (``ParallelPlan.prefix_cache``), and hit/COW/eviction
counts land in ``StepStats``,
  (c) runs **one fused decode step** for every decoding slot, with
      per-slot ``len`` vectors (the decode kernels mask per-batch
      ``cache_len``) and sampling masked by occupancy.

Two cache data planes back the slot loop (``kvcache_impl``):

* ``"paged"`` (default) — a fixed-capacity ``KVArena`` per group, sized
  from the plan (``plan.max_in_flight`` slots x paged token blocks).
  Admission scatters only the new request's pages into the arena
  (``arena.alloc`` + ``arena.write_prefill``), eviction is a free-list
  operation, and decode always runs at the full static ``(capacity, ...)``
  shape with an occupancy mask — so the fused step compiles EXACTLY ONCE
  per service no matter how the live batch size churns, and no admission
  ever copies the live batch.  For attention families the paged layout is
  NATIVE to the hot loop (``ModelApi.decode_step_paged`` /
  ``prefill_chunk_paged``): attention streams K/V in place through the
  block tables (``ops.paged_decode_attention`` /
  ``paged_chunk_attention`` — scalar-prefetch Pallas on TPU, per-slot
  up-to-len gather on CPU) and writes back only each live slot's NEW
  rows, so the old ``dense_view`` materialize / ``append_rows``
  re-scatter round trip — O(capacity x slot_tokens x layers) HBM traffic
  per emitted token — never happens.  Pure-SSM families keep the
  (already gather-free) per-slot state side-channel, and ring
  (sliding-window) layouts keep the dense-view fallback, which also
  survives as the test oracle (``paged_native=False``).
* ``"dense"`` — the pre-arena pytree path (``kvcache.select_slots`` /
  ``merge``), temporarily retained for comparison: every admission
  re-materializes the whole live cache and every live-batch-size change
  retraces the decode step.  ``benchmarks/continuous_batching.py`` reports
  both implementations' retrace counts and admission-copy bytes.

Two further **decoding modes** ride on the paged arena, gated by the
plan's task category (``ParallelPlan.speculate`` / ``n_samples``):

* **speculative** (latency services) — a small same-family draft model
  shadows each slot in its own ``KVArena``; once the draft cache catches
  up (chunked, off the decode path) each round runs k+1 fused draft
  steps and ONE fused target verify launch (``api.verify_step_paged``
  through the existing chunk-attention kernels), committing 1..k+1
  tokens.  Greedy acceptance is bit-identical to plain decode.
* **n>1 parallel sampling** (frequency services) — sibling slots fork
  off a finished prefill sharing the prompt's blocks by refcount, pay
  zero prefill compute, and diverge through copy-on-write.

Both are built on per-slot COUNTER-BASED sampling streams
(``serving/sampler.py``): each drawn token is a pure function of
(request seed, sample index, stream, emitted offset) — never of batch
composition, step count, or park/resume history.

``step()`` returns a ``StepStats`` telemetry record (results + queue-time
estimate + copy/retrace counters); the launcher feeds
``StepStats.queue_time_s`` back into the control plane's handler state
(``EdgeCloudControlPlane.set_queue_time``) so offload decisions see real
data-plane backpressure.  The pre-slot run-to-completion path is preserved
behind ``mode="sync"``; all paths produce identical greedy tokens.

Request-level DP round-robins admissions across groups (sticky for
stateful archs); sticky session pins are released through the engine's
eviction hook once a session has no queued or in-flight requests left.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.allocator import DPGroupRouter, ParallelPlan
from repro.core.categories import Outcome
from repro.models.config import ModelConfig
from repro.models.registry import ModelApi, model_api
from repro.obs.trace import NULL_TRACER

from . import kvcache
from .admission import AdmissionController, AdmissionReject, ParkedEntry
from .arena import KVArena
from .batching import ComposedBatch, QueuedItem, make_composer
from .prefix_cache import PrefixHit, RadixPrefixCache
from .sampler import (STREAM_DECODE, STREAM_DRAFT, SamplerConfig,
                      sample_per_slot, speculative_verify)

DEFAULT_MAX_SEQ_LEN = 256
DEFAULT_BLOCK_SIZE = 32

# Families whose paged KV content is a pure function of the prompt token
# ids — the prerequisite for cross-request block sharing.  SSM/hybrid carry
# per-slot recurrent state a shared prefix cannot reconstruct, and
# enc-dec/VLM cache content depends on non-token inputs (audio embeddings,
# image prefixes), so sharing by token hash would alias distinct requests.
PREFIX_CACHEABLE_FAMILIES = ("dense", "moe")


@dataclasses.dataclass
class GenerationRequest:
    rid: int
    tokens: np.ndarray               # prompt (L,) int32
    max_new_tokens: int = 16
    stream: int = 0
    extras: Optional[Dict[str, Any]] = None   # e.g. image/frame embeddings
    submitted_s: float = 0.0
    eos_token: Optional[int] = None  # evict the slot early on this token
    deadline_s: float = 0.0          # absolute deadline in the caller's
    #                                  clock (0 = none); the admission
    #                                  controller's slack/verdict input
    seed: Optional[int] = None       # sampling stream seed (None -> rid):
    #                                  every token this request draws is a
    #                                  pure function of (seed, sample_idx,
    #                                  emitted offset), never of the batch
    n_samples: int = 1               # n-way parallel sampling: n-1 forks
    #                                  share the prompt's blocks and
    #                                  diverge by copy-on-write (capped by
    #                                  the plan's resolved_n_samples())


@dataclasses.dataclass
class GenerationResult:
    rid: int
    tokens: np.ndarray               # generated ids (n,)
    prefill_s: float                 # this request's own prefill wall time
    decode_s: float                  # admit→finish wall time (continuous)
    group: int
    admitted_s: float = 0.0          # logical clock at admission
    finished_s: float = 0.0          # logical clock at eviction
    decode_steps: int = 0            # fused steps this request took part in
    sample: int = 0                  # which of the request's n parallel
    #                                  samples this result is (0 = primary)


@dataclasses.dataclass
class StepStats:
    """One scheduling round's telemetry.  ``results`` carries the finished
    requests (what ``drain`` accumulates); the rest is the feedback the
    control plane's handler consumes (queue-time backpressure) and the
    data-plane efficiency counters the benchmarks report."""
    results: List[GenerationResult]
    now: float = 0.0
    admitted: int = 0                # requests admitted this step
    evicted: int = 0                 # slots released this step
    in_flight: int = 0               # occupied slots after the step
    pending: int = 0                 # queued requests after the step
    queue_time_s: float = 0.0        # est. wait for a new arrival (handler)
    admission_copy_bytes: int = 0    # cache bytes COPIED by slot churn this
    #                                  step (admission merges, COW copies +
    #                                  the dense impl's eviction compaction)
    chunk_write_bytes: int = 0       # cache bytes WRITTEN by chunked
    #                                  prefill this step — appends of fresh
    #                                  rows, not copies of existing cache
    #                                  (split from admission_copy_bytes so
    #                                  the zero-copy admission assertion
    #                                  measures what it claims)
    whole_cache_copies: int = 0      # live-batch copies this step (dense
    #                                  merge or select_slots compaction)
    decode_steps: int = 0            # fused decode invocations this step
    prefill_chunk_tokens: int = 0    # prompt tokens prefilled this step by
    #                                  the piggybacked chunk phase
    oneshot_prefills: int = 0        # admissions that took the one-shot
    #                                  prefill path this step (ring/sliding-
    #                                  window layouts and chunking-disabled
    #                                  configs — the documented fallback,
    #                                  now observable instead of silent)
    prefix_lookups: int = 0          # prefix-cache lookups this step
    prefix_hits: int = 0             # admissions that reused cached blocks
    prefix_hit_tokens: int = 0       # prompt tokens served from the cache
    prefix_evicted_blocks: int = 0   # cached blocks reclaimed (LRU) this step
    prefix_cow_blocks: int = 0       # copy-on-write block copies this step
    moe_dropped_tokens: float = 0.0  # MoE expert-capacity drops this step
    #                                  (token-assignments past capacity;
    #                                  nonzero under binding capacity, where
    #                                  chunked prefill may diverge)
    # -- admission-control telemetry (serving/admission.py) -------------
    rejected: List[AdmissionReject] = dataclasses.field(
        default_factory=list)        # requests shed this step, each with
    #                                  an explicit verdict — the launcher
    #                                  routes OFFLOAD verdicts through the
    #                                  handler instead of dropping them
    deadline_missed: int = 0         # DEADLINE_MISSED verdicts this step
    congestion_rejects: int = 0      # CONGESTION verdicts this step
    offload_verdicts: int = 0        # OFFLOAD verdicts this step
    failed_rejects: int = 0          # FAILED verdicts this step (fault-
    #                                  tolerance terminal verdict: lost to
    #                                  a crash/drop and out of retries)
    evacuated: int = 0               # requests stripped out by crash
    #                                  evacuation since the last step
    #                                  (returned to the supervisor for
    #                                  resubmission on survivors)
    preempted: int = 0               # live slots parked this step
    resumed: int = 0                 # parked requests re-admitted this step
    parked: int = 0                  # parked requests outstanding after
    #                                  the step (KV frozen in the arena)
    # -- speculative / parallel decoding telemetry ----------------------
    draft_steps: int = 0             # fused DRAFT decode steps this step
    verify_launches: int = 0         # fused verify launches this step
    accepted_tokens: int = 0         # target tokens committed by verify
    #                                  (acceptance rate = accepted_tokens
    #                                  / verify_launches / (k+1))
    spec_slots: int = 0              # live slots speculating after the step
    forks_spawned: int = 0           # n>1 sibling slots forked this step
    fork_shortfall: int = 0          # requested forks not spawned (slot or
    #                                  block pressure; primary still runs)
    spec_degraded: int = 0           # slots that fell back to plain decode
    #                                  this step (draft alloc failure or
    #                                  park/resume)


class _Slot:
    """One in-flight request occupying a decode slot.  Under the paged
    arena, ``slot_id`` is the request's arena slot handle (its row in the
    block table); under the dense impl it is the position in the group's
    compacted cache batch axis.

    A slot admitted through the chunked-prefill path starts with
    ``first_token=None``: it holds its arena slot while ``consumed``
    prompt tokens are written chunk by chunk, and flips into decoding via
    ``begin_decode`` when the final chunk's logits yield the first token.
    """
    __slots__ = ("req", "emitted", "done", "prefill_s", "admit_wall",
                 "decode_start_wall", "finish_wall", "admitted_s", "steps",
                 "slot_id", "prefilling", "consumed", "sample_idx", "spec",
                 "draft_len")

    def __init__(self, req: GenerationRequest, first_token: Optional[int],
                 prefill_s: float, admit_wall: float, admitted_s: float,
                 slot_id: int = -1,
                 decode_start_wall: Optional[float] = None):
        self.req = req
        self.prefill_s = prefill_s
        self.admit_wall = admit_wall
        self.finish_wall = 0.0
        self.admitted_s = admitted_s
        self.steps = 0
        self.slot_id = slot_id
        self.consumed = 0                   # prompt tokens prefilled so far
        #                                     (a prefix hit starts past 0)
        self.sample_idx = 0                 # 0 = primary; >0 = n>1 fork
        self.spec = False                   # draft slot allocated + chasing
        self.draft_len = 0                  # draft-cache rows written so far
        if first_token is None:             # chunked prefill in progress
            self.prefilling = True
            self.emitted: List[int] = []
            self.done = False
            self.decode_start_wall = admit_wall
        else:
            self.begin_decode(first_token,
                              admit_wall + prefill_s
                              if decode_start_wall is None
                              else decode_start_wall)

    def begin_decode(self, first_token: int, wall: float) -> None:
        """First token sampled: prefill COMPLETED at ``wall``.  Decode
        timing starts here — under chunking that is several steps after
        admission, so ``GenerationResult.decode_s`` stays truthful instead
        of silently absorbing the chunked prefill's wall time."""
        self.prefilling = False
        self.emitted = [first_token]
        self.decode_start_wall = wall
        self.done = (len(self.emitted) >= self.req.max_new_tokens
                     or (self.req.eos_token is not None
                         and first_token == self.req.eos_token))
        if self.done:
            self.finish_wall = wall

    def push(self, token: int) -> None:
        self.emitted.append(token)
        if (len(self.emitted) >= self.req.max_new_tokens
                or (self.req.eos_token is not None
                    and token == self.req.eos_token)):
            self.done = True
            self.finish_wall = time.perf_counter()


class _GroupState:
    """Persistent in-flight state of one DP replica group: the slot
    handles plus either a ``KVArena`` (paged) or a compacted cache pytree
    (dense)."""
    __slots__ = ("cache", "slots", "arena", "prefix", "draft")

    def __init__(self):
        self.cache = None            # dense impl only
        self.arena: Optional[KVArena] = None
        self.prefix: Optional[RadixPrefixCache] = None
        self.draft: Optional[KVArena] = None   # draft model's shadow arena
        self.slots: List[_Slot] = []

    @property
    def live(self) -> int:
        return len(self.slots)


class ServiceRuntime:
    """One deployed service: params + plan + DP groups of decode slots."""

    def __init__(self, cfg: ModelConfig, params, plan: ParallelPlan, *,
                 prefill_fn: Optional[Callable] = None,
                 decode_fn: Optional[Callable] = None,
                 sampler: SamplerConfig = SamplerConfig(), seed: int = 0,
                 impl: Optional[str] = None, mode: str = "continuous",
                 kvcache_impl: str = "paged",
                 max_seq_len: int = DEFAULT_MAX_SEQ_LEN,
                 block_size: int = DEFAULT_BLOCK_SIZE,
                 pool_blocks: Optional[int] = None,
                 chunked_prefill: Optional[bool] = None,
                 prefill_chunk: Optional[int] = None,
                 prefix_cache: Optional[Any] = None,
                 paged_native: Optional[bool] = None,
                 paged_step_builder: Optional[Callable] = None,
                 on_evict: Optional[Callable] = None,
                 admission_policy: Optional[str] = None,
                 preempt: bool = True,
                 draft_params=None, draft_cfg: Optional[ModelConfig] = None,
                 speculate: Optional[int] = None,
                 tracer=None, metrics=None,
                 obs_name: Optional[str] = None):
        if mode not in ("continuous", "sync"):
            raise ValueError(f"mode must be continuous|sync, got {mode!r}")
        if kvcache_impl not in ("paged", "dense"):
            raise ValueError(
                f"kvcache_impl must be paged|dense, got {kvcache_impl!r}")
        # paged-KV precision: the arena quantizes page pools to int8 when
        # the plan says so (explicitly or via its task category).  Dense
        # caches are never quantized — an EXPLICIT int8 ask on a dense
        # engine is a config error; the category-derived default silently
        # keeps native precision (there are no page pools to quantize).
        if getattr(plan, "kv_dtype", -1) == "int8" and kvcache_impl != "paged":
            raise ValueError(
                "kv_dtype='int8' requires kvcache_impl='paged' (only page "
                "pools are block-quantized); dense caches keep the model's "
                "native dtype")
        self.kv_dtype = (plan.resolved_kv_dtype()
                         if kvcache_impl == "paged" else "bf16")
        self.cfg = cfg
        self.params = params
        self.plan = plan
        self.mode = mode
        self.kvcache_impl = kvcache_impl
        self.max_seq_len = max_seq_len
        self.block_size = block_size
        self.pool_blocks = pool_blocks
        self.on_evict = on_evict
        # -- observability (repro/obs): default-off and byte-inert --------
        # the NULL_TRACER's ``enabled = False`` lets every call site skip
        # building args entirely; neither layer ever touches a jax value,
        # so enabling them cannot change tokens or compile counts
        self.trace = NULL_TRACER if tracer is None else tracer
        self.metrics = metrics
        self._obs_named = obs_name is not None
        self.obs_name = obs_name if obs_name is not None else cfg.name
        self.prefill_seconds = 0.0   # cumulative per-request prefill wall
        #                              time (calibration's prefill_token_s
        #                              numerator)
        self._submit_wall: Dict[int, float] = {}  # rid -> submit wall time
        self._queue_wait: Dict[int, float] = {}   # rid -> measured wait
        self.api: ModelApi = model_api(cfg)
        self.router = DPGroupRouter(plan)
        self.composer = make_composer(plan)
        self.sampler = sampler
        self._key = jax.random.PRNGKey(seed)
        self.groups: Dict[int, _GroupState] = {
            g: _GroupState() for g in range(max(1, plan.dp))}
        # deadline-aware admission control: policy from the plan's knob
        # unless overridden; "fifo" (the default) keeps the controller
        # inert — identical legacy behavior, no shedding, no preemption
        self.admission = AdmissionController(self, admission_policy,
                                             preempt=preempt)
        if self.admission.active and mode != "continuous":
            raise ValueError(
                "admission policy 'sdf' requires mode='continuous' (slack "
                "ordering and preemption act on the slot loop)")
        self.decode_steps = 0        # fused decode invocations (all groups)
        self.decode_traces = 0       # XLA (re)compilations of the fused step
        self.prefill_traces = 0
        self.admission_copy_bytes = 0
        self.chunk_write_bytes = 0   # fresh rows appended by chunked prefill
        self.whole_cache_copies = 0  # admissions that copied the live batch
        self.prefill_chunk_calls = 0  # chunk invocations (all groups)
        self.prefill_tokens_computed = 0  # prompt tokens actually run
        #                                   through prefill compute (cache
        #                                   hits skip theirs)
        self.oneshot_prefills = 0    # admissions via one-shot prefill
        self._session_refs: Dict[int, int] = {}
        self._service_ewma_s = 0.0   # EWMA of per-request service time
        self._prefix_hit_ewma = 0.0  # EWMA of cached-prompt-token fraction
        self._paged_decode_fn = None
        self._chunk_fns: Dict[Any, Callable] = {}
        self._moe_stats = None
        if cfg.family == "moe":
            # expert-capacity drop observability: chunked prefill changes
            # the routing-group granularity, so divergence under binding
            # capacity shows up as a nonzero drop counter (global per
            # process; documented in models/moe.py)
            from repro.models import moe as _moe
            _moe.enable_drop_counter(True)
            self._moe_stats = _moe.MOE_DROP_STATS

        # -- chunked (piggybacked) prefill configuration ------------------
        # ring (sliding-window) cache layouts wrap positions mod the
        # window, which the linear chunk writes do not model — those
        # configs keep the one-shot admission prefill
        ring = (cfg.sliding_window is not None
                and cfg.sliding_window < self.slot_token_budget)

        # -- paged-NATIVE hot path gating ---------------------------------
        # attention families run decode/chunk straight against the page
        # pools (zero-gather); pure-SSM families carry no paged leaves (the
        # state path is already gather-free) and ring layouts store their
        # window as per-slot state — both keep the dense-view step.
        # ``paged_native=False`` forces the dense-gather step on an
        # attention family: the benchmark/test ORACLE the native path is
        # verified bit-identical (and cheaper) against.
        native_ok = (mode == "continuous" and kvcache_impl == "paged"
                     and self.api.decode_step_paged is not None and not ring)
        if paged_native is None:
            paged_native = native_ok
        elif paged_native and not native_ok:
            raise ValueError(
                "paged_native requires mode='continuous', "
                "kvcache_impl='paged', a family with paged-native entry "
                f"points (not {cfg.family!r} with ring="
                f"{ring}) — pure-SSM families and ring (sliding-window) "
                "layouts keep the state/dense-view path")
        self.paged_native = bool(paged_native)
        self.paged_step_builder = paged_step_builder
        if chunked_prefill is None:
            chunked_prefill = (mode == "continuous"
                               and kvcache_impl == "paged" and not ring)
        elif chunked_prefill:
            if mode != "continuous" or kvcache_impl != "paged":
                raise ValueError("chunked_prefill requires "
                                 "mode='continuous' + kvcache_impl='paged'")
            if ring:
                raise ValueError("chunked_prefill does not support ring "
                                 "(sliding-window) cache layouts")
        self.chunked_prefill = bool(chunked_prefill)
        # ring layouts silently took the one-shot path before; the fallback
        # is now an explicit, counted state (StepStats.oneshot_prefills)
        self.ring_fallback = bool(ring and mode == "continuous"
                                  and kvcache_impl == "paged"
                                  and not self.chunked_prefill)
        # explicit chunk sizes are validated, not silently rounded: the
        # chunk is the arena's scatter unit, so it must be a positive
        # multiple of the block size (mirrored by launch/serve.py's flags)
        explicit_chunk = (prefill_chunk if prefill_chunk is not None
                          else (plan.prefill_chunk or None))
        if explicit_chunk is not None:
            chunk = int(explicit_chunk)
            if chunk <= 0 or chunk % block_size:
                raise ValueError(
                    f"prefill_chunk must be a positive multiple of "
                    f"block_size={block_size}, got {chunk}")
        else:
            chunk = plan.prefill_chunk_tokens(block_size)
        self.prefill_chunk_tokens = min(chunk, self.slot_token_budget)
        self.chunk_buckets = self._derive_buckets(self.prefill_chunk_tokens)

        # -- prefix cache (radix shared-prefix KV reuse) ------------------
        if prefix_cache is None:
            knob = plan.prefix_cache
            explicit_prefix = False
        else:
            knob = (-1 if prefix_cache is True
                    else 0 if prefix_cache is False else int(prefix_cache))
            if knob < -1:
                raise ValueError(
                    f"prefix_cache must be -1 (category default), 0 "
                    f"(disabled) or a positive retention block count; got "
                    f"{knob}")
            explicit_prefix = knob != 0
        cacheable = (mode == "continuous" and kvcache_impl == "paged"
                     and self.chunked_prefill
                     and cfg.family in PREFIX_CACHEABLE_FAMILIES)
        if explicit_prefix and not cacheable:
            raise ValueError(
                "prefix_cache requires mode='continuous', "
                "kvcache_impl='paged', chunked prefill (so hits resume "
                f"mid-prompt) and a family in {PREFIX_CACHEABLE_FAMILIES} "
                "(paged KV must be a pure function of prompt tokens); got "
                f"family={cfg.family!r}, mode={mode!r}, "
                f"kvcache_impl={kvcache_impl!r}, "
                f"chunked_prefill={self.chunked_prefill}")
        self._prefix_knob = knob
        self.prefix_cache_enabled = bool(cacheable and knob != 0)

        # -- speculative decoding (draft/verify) --------------------------
        # latency-category services trade draft FLOPs for fewer serial
        # target launches: a small draft model proposes k tokens, the
        # target scores all k+1 in ONE fused verify launch
        # (api.verify_step_paged through the existing chunk-attention
        # kernels).  Greedy acceptance keeps tokens bit-identical to the
        # non-speculative engine; stochastic acceptance is exact
        # leave-one-out rejection sampling (serving/sampler.py).
        if (draft_params is None) != (draft_cfg is None):
            raise ValueError("draft_params and draft_cfg come together")
        have_draft = draft_params is not None
        knob_k = (plan.resolved_speculate(have_draft) if speculate is None
                  else int(speculate))
        if knob_k > 0 and not have_draft:
            raise ValueError(
                f"speculate={knob_k} requires a draft model (draft_params "
                "+ draft_cfg); the category default degrades to 0 without "
                "one, an explicit ask does not")
        if knob_k > 0:
            draft_ring = (draft_cfg.sliding_window is not None
                          and draft_cfg.sliding_window
                          < self.slot_token_budget)
            spec_ok = (mode == "continuous" and kvcache_impl == "paged"
                       and self.paged_native and self.chunked_prefill
                       and cfg.family in PREFIX_CACHEABLE_FAMILIES
                       and draft_cfg.family == cfg.family
                       and draft_cfg.vocab_size == cfg.vocab_size
                       and self.api.verify_step_paged is not None
                       and not draft_ring)
            if not spec_ok:
                raise ValueError(
                    "speculative decoding requires mode='continuous', "
                    "kvcache_impl='paged', paged_native, chunked_prefill, "
                    f"a family in {PREFIX_CACHEABLE_FAMILIES} with a "
                    "verify entry point, and a same-family same-vocab "
                    "non-ring draft; got "
                    f"family={cfg.family!r}/{draft_cfg.family!r}, "
                    f"vocab={cfg.vocab_size}/{draft_cfg.vocab_size}, "
                    f"mode={mode!r}, kvcache_impl={kvcache_impl!r}, "
                    f"paged_native={self.paged_native}, "
                    f"chunked_prefill={self.chunked_prefill}, "
                    f"draft_ring={draft_ring}")
        self.speculate_k = knob_k
        self.draft_params = draft_params
        self.draft_cfg = draft_cfg
        self.draft_api: Optional[ModelApi] = (
            model_api(draft_cfg) if have_draft else None)
        self._draft_chunk_fns: Dict[Any, Callable] = {}
        self._draft_decode_fn = None
        self._verify_fn = None
        self.draft_steps = 0         # fused draft decode invocations
        self.verify_launches = 0     # fused verify invocations
        self.accepted_tokens = 0     # target tokens committed by verify
        self.spec_degraded = 0       # speculation fallbacks (alloc/park)
        self.verify_traces = 0       # XLA (re)compilations of verify
        self.draft_decode_traces = 0
        self.draft_prefill_traces = 0
        self.draft_prefill_tokens = 0

        # -- fault tolerance (crash evacuation, core/faults.py) -----------
        self.evacuations = 0         # crash evacuations of this runtime
        self.evacuated_requests = 0  # requests stripped out across them
        self._evacuated_pending = 0  # delta reported by the next StepStats

        # -- n>1 parallel sampling (refcounted prompt-block forks) --------
        self.forks_spawned = 0
        self.fork_shortfall = 0
        self._sibling_refs: Dict[int, int] = {}   # rid -> live siblings
        self.n_samples_cap = (plan.resolved_n_samples()
                              if (mode == "continuous"
                                  and kvcache_impl == "paged"
                                  and self.chunked_prefill) else 1)
        api = self.api

        if prefill_fn is None:
            def _prefill(p, b, cs):
                self.prefill_traces += 1    # runs at trace time only
                return api.prefill(p, cfg, b, cache_size=cs, impl=impl)
            prefill_fn = jax.jit(_prefill, static_argnums=(2,))
        if decode_fn is None:
            def _decode(p, t, c):
                self.decode_traces += 1     # runs at trace time only
                return api.decode_step(p, cfg, t, c, impl=impl)
            decode_fn = jax.jit(_decode)
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self._impl = impl

    @property
    def slot_token_budget(self) -> int:
        """Cache tokens one arena slot can hold (block-rounded
        ``max_seq_len``); a request's prompt + family extras + max_new
        must fit."""
        blocks = max(1, -(-self.max_seq_len // self.block_size))
        return blocks * self.block_size

    def _derive_buckets(self, chunk: int):
        """Static chunk shapes the engine compiles: power-of-two multiples
        of ``block_size`` up to the category's chunk size.  The smallest
        bucket is always one block, so a final partial chunk never
        overshoots the slot budget."""
        buckets, b = [], self.block_size
        while b < chunk:
            buckets.append(b)
            b *= 2
        buckets.append(chunk)
        return tuple(sorted(set(buckets)))

    def _pick_bucket(self, remaining: int,
                     budget: Optional[int] = None) -> Optional[int]:
        """Largest bucket that fits the remaining prompt, else the
        smallest (one-block) bucket for the final partial chunk — never
        exceeding the step's remaining token ``budget`` (None when the
        budget cannot afford even the smallest bucket: the caller defers
        the chunk to the next step, keeping the per-step prefill spend at
        or under ``prefill_chunk`` tokens)."""
        affordable = (self.chunk_buckets if budget is None else
                      [b for b in self.chunk_buckets if b <= budget])
        if not affordable:
            return None
        for b in reversed(affordable):
            if b <= remaining:
                return b
        return affordable[0]

    # -- queue ------------------------------------------------------------
    def submit(self, req: GenerationRequest, now: float = 0.0) -> None:
        if self.kvcache_impl == "paged" and self.mode == "continuous":
            # reject over-budget requests at the door: raising later, mid-
            # admission, would drop the composed batch's other members and
            # leak their session pins
            total = (len(req.tokens) + self._extra_cache_tokens()
                     + req.max_new_tokens)
            if total > self.slot_token_budget:
                raise ValueError(
                    f"request {req.rid} needs {total} cache tokens > "
                    f"per-slot budget {self.slot_token_budget}; raise "
                    f"max_seq_len")
        if self.plan.sticky and req.stream:
            self._session_refs[req.stream] = \
                self._session_refs.get(req.stream, 0) + 1
        if self.metrics is not None or self.trace.enabled:
            self._submit_wall[req.rid] = time.perf_counter()
        tr = self.trace
        if tr.enabled:
            tid = str(req.rid)
            tr.begin(self.obs_name, tid, "request",
                     prompt_tokens=len(req.tokens),
                     max_new=req.max_new_tokens, n_samples=req.n_samples)
            tr.begin(self.obs_name, tid, "queued")
        self.composer.add(QueuedItem(payload=req, stream=req.stream,
                                     enqueued_s=now, rid=req.rid))

    def pending(self) -> int:
        return len(self.composer)

    def in_flight(self) -> int:
        return sum(g.live for g in self.groups.values())

    def total_slots(self) -> int:
        return self.plan.max_in_flight * len(self.groups)

    # -- shared helpers ---------------------------------------------------
    def _pad_prompts(self, reqs: Sequence[GenerationRequest]):
        L = max(len(r.tokens) for r in reqs)
        toks = np.zeros((len(reqs), L), np.int32)
        lens = np.zeros((len(reqs),), np.int32)
        for i, r in enumerate(reqs):
            toks[i, L - len(r.tokens):] = r.tokens   # left-pad
            lens[i] = len(r.tokens)
        return jnp.asarray(toks), lens

    def _build_batch(self, reqs: Sequence[GenerationRequest], toks):
        batch: Dict[str, Any] = {"tokens": toks}
        if self.cfg.family in ("audio", "vlm"):
            embs = [r.extras["embeddings"] for r in reqs]
            batch["embeddings"] = jnp.asarray(np.stack(embs))
        return batch

    def _extra_cache_tokens(self) -> int:
        """Cache positions a request consumes beyond its text prompt: the
        VLM family's image prefix rides along in the decoder cache (its
        ``prefill`` budgets ``cache_size`` in TEXT tokens and adds the
        prefix itself)."""
        return self.cfg.prefix_len if self.cfg.family == "vlm" else 0

    def _req_seed(self, req: GenerationRequest) -> int:
        """The request's sampling-stream seed (``rid`` unless the caller
        pinned one) — with the per-slot counter streams below, a request's
        tokens are a pure function of this seed, never of which other
        requests share its fused batch."""
        return req.rid if req.seed is None else int(req.seed)

    def _sample(self, logits, seeds, sample_ids, offsets,
                live=None, occupancy=None, stream: int = STREAM_DECODE):
        """Per-slot counter-based sampling (the batch-composition bugfix).

        The old path split ``self._key`` once per fused step and drew the
        whole batch from the split — so every request's tokens depended on
        HOW MANY steps the engine had taken and WHICH slots were live:
        admitting an unrelated request changed another request's output,
        and park/resume shifted the stream.  Now each row's key is
        ``fold_in(fold_in(fold_in(fold_in(base, seed), sample_idx),
        stream), offset)`` — a pure function of the request's own
        identity and its emitted length at the draw — so tokens are
        bit-identical alone, in any batch mix, and across park/resume or
        speculative on/off (greedy never touches a key at all)."""
        return sample_per_slot(
            logits, self._key, np.asarray(seeds, np.uint32),
            np.asarray(sample_ids, np.uint32),
            np.asarray(offsets, np.uint32), self.sampler, stream=stream,
            live=live, occupancy=occupancy)

    def _obs_admitted(self, req: GenerationRequest, group: int,
                      next_span: str, **args) -> None:
        """Observability at an admission transition: record the measured
        queue wait (submit -> first admission; resumes keep the
        original), close the request's innermost open span (``queued``,
        or ``parked`` on a resume) and open the next lifecycle span."""
        if self._submit_wall:
            t = self._submit_wall.pop(req.rid, None)
            if t is not None:
                self._queue_wait[req.rid] = max(
                    0.0, time.perf_counter() - t)
        tr = self.trace
        if tr.enabled:
            tid = str(req.rid)
            tr.end(self.obs_name, tid, group=group)
            tr.begin(self.obs_name, tid, next_span, **args)

    def _slot_tid(self, s: _Slot) -> str:
        """The slot's trace timeline: the request id, with n>1 sampling
        forks on their own ``rid.sample`` lane."""
        return (str(s.req.rid) if s.sample_idx == 0
                else f"{s.req.rid}.{s.sample_idx}")

    def _finish_request(self, req: GenerationRequest, group: int) -> None:
        """Session-pin bookkeeping + user hook, fired whenever a request
        leaves the data plane (slot eviction or sync-batch completion)."""
        self._submit_wall.pop(req.rid, None)
        self._queue_wait.pop(req.rid, None)
        if self.trace.enabled:
            # balanced no matter where the request died: close() ends
            # every still-open span (a shed request's verdict close
            # already emptied the stack, making this a no-op)
            self.trace.close(self.obs_name, str(req.rid), outcome="served")
        if self.plan.sticky and req.stream:
            left = self._session_refs.get(req.stream, 1) - 1
            if left <= 0:
                self._session_refs.pop(req.stream, None)
                self.router.release(req.stream)
            else:
                self._session_refs[req.stream] = left
        if self.on_evict is not None:
            self.on_evict(req, group)

    def _finish_sibling(self, req: GenerationRequest, group: int) -> None:
        """Eviction-side bookkeeping for n>1 sampling: a forked request's
        session pins and eviction hook fire once — when its LAST sibling
        slot leaves the data plane, not once per sample."""
        refs = self._sibling_refs.get(req.rid)
        if refs is None:
            self._finish_request(req, group)
            return
        if refs <= 1:
            self._sibling_refs.pop(req.rid, None)
            self._finish_request(req, group)
        else:
            self._sibling_refs[req.rid] = refs - 1

    def _note_service_time(self, res: GenerationResult) -> None:
        if res.sample == 0:
            # forks carry the primary's prefill_s but paid no prefill
            # compute: count the wall time once or the calibration's
            # prefill_token_s numerator double-counts
            self.prefill_seconds += max(0.0, res.prefill_s)
        t = max(1e-6, res.prefill_s + max(0.0, res.decode_s))
        self._service_ewma_s = (t if self._service_ewma_s == 0.0
                                else 0.8 * self._service_ewma_s + 0.2 * t)

    def queue_time_estimate(self) -> float:
        """Expected wait before a newly queued request starts decoding —
        the handler's queue-time feedback signal (Eq. 1 exclusion uses
        it to skip backlogged peers).  Under chunked prefill the queued
        PROMPT TOKENS matter too: the (b2) phase drains at most one chunk
        budget per group per step, so a prompt-heavy queue is priced as
        the extra request-waves those chunks occupy."""
        if self._service_ewma_s <= 0.0:
            return 0.0
        waves = self.pending() / max(1, self.total_slots())
        if self.chunked_prefill and self.prefill_chunk_tokens > 0:
            # queued prompts PLUS admitted-but-unconsumed ones: a long
            # prompt leaves the composer at alloc time but keeps eating
            # (b2) budget until its last chunk lands
            queued = self.composer.pending_prefill_tokens()
            if self.prefix_cache_enabled:
                # cached-token term: the observed hit-rate EWMA predicts
                # the fraction of QUEUED prompt tokens the prefix cache
                # will serve without compute, so the handler's queue-time
                # signal doesn't overprice repeated-prefix (frequency)
                # traffic.  In-flight unconsumed tokens are already
                # post-hit (slots admit with consumed = hit_tokens), so
                # they are not discounted again.
                queued *= max(0.0, 1.0 - self._prefix_hit_ewma)
            backlog = queued + self._unconsumed_prompt_tokens()
            chunk_steps = backlog / (self.prefill_chunk_tokens
                                     * max(1, len(self.groups)))
            waves += chunk_steps / max(1, self.total_slots())
        return waves * self._service_ewma_s

    def _unconsumed_prompt_tokens(self) -> int:
        """Prompt tokens of in-flight slots still awaiting their chunks."""
        return sum(len(s.req.tokens) - s.consumed
                   for g in self.groups.values() for s in g.slots
                   if s.prefilling)

    # ------------------------------------------------------------------
    # continuous mode: slot admit / fused decode / evict
    # ------------------------------------------------------------------
    def _free_slots(self) -> int:
        return sum(max(0, self.plan.bs - g.live)
                   for g in self.groups.values())

    def _evict(self, group: int, state: _GroupState,
               now: float) -> List[GenerationResult]:
        """(a) Release every slot whose request finished.  Paged: a pure
        free-list operation per slot.  Dense: compact the cache batch axis
        with select_slots (a whole-batch copy)."""
        if not state.slots:
            return []
        keep = [i for i, s in enumerate(state.slots) if not s.done]
        if len(keep) == len(state.slots):
            return []
        results = []
        for s in state.slots:
            if not s.done:
                continue
            res = GenerationResult(
                rid=s.req.rid, tokens=np.asarray(s.emitted, np.int32),
                prefill_s=s.prefill_s,
                decode_s=max(0.0, s.finish_wall - s.decode_start_wall),
                group=group, admitted_s=s.admitted_s, finished_s=now,
                decode_steps=s.steps, sample=s.sample_idx)
            results.append(res)
            self._note_service_time(res)
            self.admission.observe(res)
            if self.trace.enabled:
                self.trace.end(self.obs_name, self._slot_tid(s),
                               tokens=len(s.emitted), steps=s.steps)
            if self.metrics is not None:
                n = len(s.emitted)
                self.metrics.observe_request(
                    self.obs_name,
                    ttft_s=max(0.0, s.decode_start_wall - s.admit_wall),
                    tpot_s=(res.decode_s / (n - 1)) if n > 1 else None,
                    queue_wait_s=self._queue_wait.get(s.req.rid, 0.0),
                    new_tokens=n)
            if state.arena is not None:
                if s.spec and state.draft is not None:
                    state.draft.free(s.slot_id)
                    s.spec = False
                if state.prefix is not None and not s.prefilling:
                    # the slot will never write again: its partial tail
                    # block's prompt content is final, so it can join the
                    # index (sharers mask the generated tokens past the
                    # entry's valid count and COW before writing)
                    state.prefix.insert(
                        s.req.tokens,
                        state.arena._block_tables[s.slot_id])
                state.arena.free(s.slot_id)
            self._finish_sibling(s.req, group)
        state.slots = [state.slots[i] for i in keep]
        if state.arena is None:
            state.cache = (kvcache.select_slots(state.cache, keep)
                           if keep else None)
            if keep:                 # compaction re-materialized the batch
                self.whole_cache_copies += 1
                self.admission_copy_bytes += kvcache.cache_bytes(state.cache)
        return results

    def _ensure_arena(self, state: _GroupState) -> KVArena:
        if state.arena is None:
            state.arena = KVArena(
                self.cfg, self.api.init_cache,
                capacity=self.plan.max_in_flight,
                max_seq_len=self.max_seq_len, block_size=self.block_size,
                pool_blocks=self.pool_blocks, kv_dtype=self.kv_dtype)
            if self.prefix_cache_enabled:
                state.prefix = RadixPrefixCache(
                    state.arena,
                    retention_blocks=self.plan.prefix_cache_blocks(
                        state.arena.pool_blocks, override=self._prefix_knob))
        return state.arena

    def _admit_one(self, req: GenerationRequest, group: int,
                   state: _GroupState, now: float,
                   pending_cows: Optional[List] = None) -> bool:
        """(b) Claim a slot for one admission.  Chunked paged: just an
        arena ``alloc`` — the prompt is prefilled chunk by chunk in the
        (b2) phase, so admission itself never stalls the step.  Unchunked
        paged: one-shot prefill + page scatter.  Dense: one-shot prefill +
        kvcache.merge (re-materializes everything).  Returns False when
        the arena is out of blocks (caller requeues).

        ``pending_cows`` collects this wave's divergence copy-on-writes
        (partial-tail prefix hits) instead of dispatching one jitted
        single-block copy per admission: ``_admit`` flushes them in ONE
        batched ``arena.cow_blocks`` scatter after the wave — the common
        templated-prompt burst (several admissions sharing one template)
        pays one dispatch, not one per member."""
        extra = self._extra_cache_tokens()
        if self.kvcache_impl == "paged":
            arena = self._ensure_arena(state)
            total = len(req.tokens) + extra + req.max_new_tokens
            if total > arena.slot_tokens:
                raise ValueError(
                    f"request {req.rid} needs {total} tokens > per-slot "
                    f"budget {arena.slot_tokens}; raise max_seq_len")
            entry = self.admission.parked.get(req.rid)
            if entry is not None:
                return self._resume_parked(req, state, entry, total,
                                           pending_cows)
            if self.chunked_prefill:
                # prefix-cache lookup: stitch the longest cached prefix
                # into the new slot's block table; chunked prefill then
                # starts AFTER the hit boundary
                hit: Optional[PrefixHit] = None
                pc = state.prefix
                looked = pc is not None and len(req.tokens) > 1
                if looked:
                    h = pc.lookup(req.tokens)
                    if h.tokens > 0:
                        hit = h
                # blocks already promised to this wave's deferred COWs
                # must stay claimable until the flush
                reserved = len(pending_cows) if pending_cows else 0
                if hit is not None and hit.partial_valid:
                    # a partial-tail share ALWAYS needs its divergence COW
                    # (the first computed token lands inside that block),
                    # so admit only with headroom for the copy; under a
                    # tight pool degrade to the full-block hit instead of
                    # failing mid-step
                    if not arena.can_alloc(total, shared=hit.blocks,
                                           reserve=1 + reserved):
                        hit = (PrefixHit(blocks=hit.blocks[:-1],
                                         tokens=hit.full_blocks
                                         * arena.block_size,
                                         full_blocks=hit.full_blocks,
                                         partial_valid=0)
                               if hit.full_blocks else None)
                shared = hit.blocks if hit is not None else ()
                if not arena.can_alloc(total, shared=shared,
                                       reserve=reserved):
                    return False
                slot_id = arena.alloc(total, shared=shared)
                if hit is not None:
                    arena.set_len(slot_id, hit.tokens)
                    if hit.partial_valid:
                        # divergence copy, deferred to the wave's batched
                        # flush (headroom was reserved above;
                        # ensure_writable in the chunk and decode paths
                        # stays as an invariant guard)
                        if pending_cows is not None:
                            pending_cows.append((slot_id, hit.full_blocks))
                        else:
                            arena.cow_block(slot_id, hit.full_blocks)
                            self.admission_copy_bytes += (
                                arena.block_size * arena.token_bytes)
                else:
                    arena.reset_len(slot_id)
                slot = _Slot(req, None, prefill_s=0.0,
                             admit_wall=time.perf_counter(),
                             admitted_s=now, slot_id=slot_id)
                if hit is not None:
                    slot.consumed = hit.tokens
                if looked:
                    pc.record(hit, len(req.tokens))
                if pc is not None:
                    # EWMA over ALL admissions (1-token prompts count as
                    # misses) so the queue-time discount stays honest
                    frac = ((hit.tokens / len(req.tokens))
                            if hit is not None else 0.0)
                    self._prefix_hit_ewma = (0.8 * self._prefix_hit_ewma
                                             + 0.2 * frac)
                state.slots.append(slot)
                self._obs_admitted(req, group, "prefill",
                                   hit_tokens=slot.consumed)
                return True
            if not arena.can_alloc(total):
                return False
            # cache_size is budgeted in text tokens; family extras (VLM
            # prefix) ride along so the model-built cache lands exactly on
            # the arena's slot_tokens sequence axis
            cache_size = arena.slot_tokens - extra
        else:
            cache_size = int(len(req.tokens) + req.max_new_tokens)

        self._obs_admitted(req, group, "prefill", oneshot=True)
        t0 = time.perf_counter()
        toks, _ = self._pad_prompts([req])
        batch = self._build_batch([req], toks)
        logits, cache = self.prefill_fn(self.params, batch, cache_size)
        first = int(np.asarray(self._sample(
            logits, [self._req_seed(req)], [0], [0]))[0])
        jax.block_until_ready(logits)
        t1 = time.perf_counter()
        self.oneshot_prefills += 1
        self.prefill_tokens_computed += len(req.tokens)

        if self.kvcache_impl == "paged":
            slot_id = arena.alloc(total)
            self.admission_copy_bytes += arena.write_prefill(
                slot_id, cache, prompt_len=len(req.tokens) + extra)
        else:
            slot_id = len(state.slots)
            cache = kvcache.with_lens(cache, kvcache.lens(cache))
            self.admission_copy_bytes += kvcache.cache_bytes(cache)
            if state.cache is None:
                state.cache = cache
            else:
                # the merge copies the entire live batch to admit one row
                self.admission_copy_bytes += kvcache.cache_bytes(state.cache)
                self.whole_cache_copies += 1
                state.cache = kvcache.merge([state.cache, cache])
        state.slots.append(_Slot(req, first, prefill_s=t1 - t0,
                                 admit_wall=t0, admitted_s=now,
                                 slot_id=slot_id, decode_start_wall=t1))
        tr = self.trace
        if tr.enabled:
            tid = str(req.rid)
            tr.end(self.obs_name, tid, tokens_computed=len(req.tokens))
            tr.instant(self.obs_name, tid, "first_token")
            tr.begin(self.obs_name, tid, "decode")
        return True

    def _resume_parked(self, req: GenerationRequest, state: _GroupState,
                       entry: ParkedEntry, total: int,
                       pending_cows: Optional[List] = None) -> bool:
        """Re-admit a preempted request onto its parked blocks: alloc with
        ``shared=blocks`` re-increfs every block (a 100% prefix hit over
        the WHOLE parked content, generated tokens included), then the
        parked hold drops — net refcounts unchanged, zero prefill, zero
        copies.  The slot resumes at the exact device length and emitted
        tokens of park time, so greedy continuation is bit-identical."""
        arena = state.arena
        reserved = len(pending_cows) if pending_cows else 0
        if not arena.can_alloc(total, shared=entry.blocks,
                               reserve=reserved):
            return False
        slot_id = arena.alloc(total, shared=entry.blocks)
        arena.release_parked(entry.blocks)
        arena.set_len(slot_id, entry.cache_len)
        slot = _Slot(req, None, prefill_s=entry.prefill_s,
                     admit_wall=entry.admit_wall,
                     admitted_s=entry.admitted_s, slot_id=slot_id)
        slot.prefilling = False
        slot.emitted = list(entry.emitted)
        slot.decode_start_wall = entry.decode_start_wall
        slot.steps = entry.steps
        slot.consumed = entry.consumed
        state.slots.append(slot)
        self.admission.pop_parked(req.rid)
        self.admission.note_resume()
        if state.prefix is not None:
            # a resume is the prefix cache's best case: the entire parked
            # content (prompt AND generated KV) is served from resident
            # blocks — count it so the hit telemetry reflects the reuse
            state.prefix.note_resume(entry.cache_len)
        self._obs_admitted(req, entry.group, "decode", resumed=True)
        return True

    def _park_slot(self, group: int, state: _GroupState, s: _Slot,
                   now: float) -> None:
        """Preempt one live decode slot by block-table parking: freeze its
        blocks in the arena (KV stays resident, references held by the
        ``ParkedEntry``), free the slot, and re-queue the request — its
        later compose resumes via ``_resume_parked``."""
        arena = state.arena
        if s.spec and state.draft is not None:
            # the draft cache is disposable state (re-derivable from the
            # tokens) but re-chasing it after resume isn't worth the
            # chunks: a parked request resumes NON-speculative.  Greedy
            # spec-on/spec-off is bit-identical, so the degradation is
            # invisible in the tokens — only in the telemetry.
            state.draft.free(s.slot_id)
            s.spec = False
            s.draft_len = 0
            self.spec_degraded += 1
        entry = ParkedEntry(
            req=s.req, group=group,
            blocks=[], cache_len=int(arena.lens[s.slot_id]),
            emitted=list(s.emitted), consumed=s.consumed, steps=s.steps,
            prefill_s=s.prefill_s, admit_wall=s.admit_wall,
            decode_start_wall=s.decode_start_wall,
            admitted_s=s.admitted_s, parked_s=now)
        entry.blocks = arena.park(s.slot_id)
        state.slots.remove(s)
        self.admission.note_park(entry)
        tr = self.trace
        if tr.enabled:
            tid = str(s.req.rid)
            tr.end(self.obs_name, tid, reason="park",
                   tokens=len(s.emitted))
            tr.begin(self.obs_name, tid, "parked")
        self.composer.add(QueuedItem(payload=s.req, stream=s.req.stream,
                                     enqueued_s=now, rid=s.req.rid))

    def _maybe_preempt(self, now: float) -> None:
        """Park the laziest live decode slot when the most urgent pending
        request would otherwise miss its deadline waiting.  One victim per
        step bounds churn; the controller's guard ensures the victim can
        afford the round trip."""
        ctrl = self.admission
        if not (ctrl.active and ctrl.preempt
                and self.kvcache_impl == "paged"):
            return
        if self._free_slots() > 0 or len(ctrl.parked) >= ctrl.max_parked:
            return
        head = self.composer.peek()
        if head is None:
            return
        urgent_slack = ctrl.slack(head.payload, now)
        if not 0.0 <= urgent_slack < float("inf"):
            return                   # doomed (shed next round) or lax
        if urgent_slack >= ctrl.wait_estimate(now):
            return                   # it can afford to wait its turn
        candidates = []
        for g, state in self.groups.items():
            arena = state.arena
            if arena is None or not arena.parkable:
                continue             # per-slot state can't survive parking
            for s in state.slots:
                if s.done or s.prefilling or s.req.rid == head.rid:
                    continue
                if s.req.rid in self._sibling_refs:
                    # n>1 siblings share one request identity: parking one
                    # fork would re-queue the rid while other samples keep
                    # decoding it — resume would then double-admit
                    continue
                candidates.append((ctrl.slot_slack(s, now),
                                   ctrl.remaining_estimate(s),
                                   (g, state, s)))
        victim = ctrl.pick_victim(urgent_slack, candidates)
        if victim is not None:
            self._park_slot(*victim, now)

    def _shed_rejected(self, now: float) -> List[AdmissionReject]:
        """Run the controller's shed pass and finalize each reject: parked
        blocks are released back to the arena (cached ones fall to the
        idle LRU), session pins drop, and the eviction hook fires — every
        shed request leaves the data plane carrying its verdict."""
        rejects: List[AdmissionReject] = []
        for item, verdict in self.admission.shed(now):
            req = item.payload
            entry = self.admission.pop_parked(item.rid)
            if entry is not None:
                self.groups[entry.group].arena.release_parked(entry.blocks)
            if self.trace.enabled:
                # the verdict lands on the outermost ("request") span;
                # _finish_request's defensive close then no-ops
                self.trace.close(self.obs_name, str(req.rid),
                                 verdict=verdict.name)
            self._finish_request(req, -1)
            rejects.append(AdmissionReject(req=req, verdict=verdict,
                                           now=now))
        return rejects

    def _take_evacuated(self) -> int:
        """Evacuations since the last step, folded into ``StepStats``."""
        n = self._evacuated_pending
        self._evacuated_pending = 0
        return n

    def evacuate(self, now: float = 0.0) -> List[GenerationRequest]:
        """Crash this runtime's data plane (``core/faults.py`` adversary):
        strip every queued, in-flight and parked request out and return
        them rid-deduplicated for resubmission elsewhere.  In-flight KV
        state is lost with the process — survivors must re-prefill (the
        radix prefix cache makes that cheap when they land back here after
        a restart, so the warm prefix index is deliberately NOT torn
        down).  PR 8's counter-stream sampling makes the resubmitted
        request's tokens bit-identical on any replica, which is what lets
        recovery re-run prefill without corrupting the output."""
        out: Dict[int, GenerationRequest] = {}
        # (1) queued work — includes rids _park_slot re-queued
        for item, _ in self.composer.shed(lambda item: True):
            req = item.payload
            out.setdefault(req.rid, req)
        # (2) live slots: free draft/paged state per slot, then drop the
        # whole batch.  No prefix insert — the slot died mid-flight and
        # resubmission re-prefills from the index as it stands.  Slot rids
        # are finished HERE (via the sibling refcount, once per rid) and
        # skipped in the final pass; queued/parked rids never overlap
        # live slots, so no rid is finished twice.
        slot_rids: set = set()
        for group, state in self.groups.items():
            for s in state.slots:
                if s.spec and state.draft is not None:
                    state.draft.free(s.slot_id)
                    s.spec = False
                if state.arena is not None:
                    state.arena.free(s.slot_id)
                if self.trace.enabled:
                    self.trace.close(self.obs_name, self._slot_tid(s),
                                     outcome="evacuated")
                out.setdefault(s.req.rid, s.req)
                slot_rids.add(s.req.rid)
                self._finish_sibling(s.req, group)
            state.slots = []
            if state.arena is None:
                state.cache = None
        # (3) parked entries: their frozen blocks go back to the arena
        # (the rid itself is already in ``out`` via the composer drain)
        for rid in list(self.admission.parked):
            entry = self.admission.pop_parked(rid)
            if entry is None:
                continue
            arena = self.groups[entry.group].arena
            if arena is not None:
                arena.release_parked(entry.blocks)
            out.setdefault(entry.req.rid, entry.req)
        for req in out.values():
            if req.rid in slot_rids:
                continue
            if self.trace.enabled:
                self.trace.close(self.obs_name, str(req.rid),
                                 outcome="evacuated")
            self._finish_request(req, -1)
        self.evacuations += 1
        self.evacuated_requests += len(out)
        self._evacuated_pending += len(out)
        return list(out.values())

    def _route_admission(self, item: QueuedItem) -> Optional[int]:
        """Pick a DP group with a free slot; sticky sessions must land on
        their pinned group or wait.  A parked request is pinned harder
        still: its frozen blocks are physical ids in ONE group's arena."""
        pg = self.admission.parked_group(item.rid)
        if pg is not None:
            return pg if self.groups[pg].live < self.plan.bs else None
        g = self.router.route(session=item.stream)
        if self.groups[g].live < self.plan.bs:
            return g
        if self.plan.sticky and item.stream:
            return None          # session pinned to a full group: requeue
        for alt, state in self.groups.items():
            if state.live < self.plan.bs:
                return alt
        return None

    def _admit(self, now: float, max_wait_s: float) -> int:
        free = self._free_slots()
        if free <= 0 or not len(self.composer):
            return 0
        composed = self.composer.compose(limit=free, now=now,
                                         max_wait_s=max_wait_s)
        if composed is None:
            return 0
        admitted = 0
        unplaced = []
        pending_cows: Dict[int, List] = {g: [] for g in self.groups}
        for item in composed.items:
            g = self._route_admission(item)
            if g is None or not self._admit_one(item.payload, g,
                                                self.groups[g], now,
                                                pending_cows[g]):
                unplaced.append(item)
                continue
            admitted += 1
        # flush the wave's deferred divergence COWs: admissions sharing a
        # template coalesce their single-block copies into one batched
        # scatter per group (arena.cow_blocks) instead of one jit dispatch
        # per admission
        for g, pairs in pending_cows.items():
            if pairs:
                arena = self.groups[g].arena
                copied = arena.cow_blocks(pairs)
                self.admission_copy_bytes += (copied * arena.block_size
                                              * arena.token_bytes)
        for item in reversed(unplaced):   # push_front in reverse keeps FIFO
            self.composer.push_front(item)
        self.admission.note_admit(admitted)
        return admitted

    # -- chunked piggybacked prefill (paged arena only) -----------------
    def _build_chunk_fn(self, arena: KVArena, T: int, with_emb: bool,
                        api: Optional[ModelApi] = None,
                        cfg: Optional[ModelConfig] = None,
                        native: Optional[bool] = None,
                        counter: str = "prefill_traces"):
        """One jitted chunk step per (bucket, first-chunk) shape.

        Paged-NATIVE (attention families): run ``prefill_chunk_paged``
        straight against the page pools — chunk K/V rows scatter in place
        through the slot's block-table row, no dense view is gathered or
        re-scattered.  Fallback (pure-SSM, ring layouts, or the forced
        oracle): gather the slot's dense view, run ``prefill_chunk``, and
        scatter the written rows back via the multi-token
        ``append_rows``.

        ``api``/``cfg``/``native``/``counter`` default to the TARGET
        model; the speculative path passes the DRAFT model's to build its
        catch-up chunk step over the draft arena (compiles counted under
        ``draft_prefill_traces`` so the target's one-trace assertions stay
        meaningful)."""
        api = self.api if api is None else api
        cfg = self.cfg if cfg is None else cfg
        impl = self._impl
        # cache rows one call writes: the text bucket, plus the VLM image
        # prefix that rides along with the first chunk
        n_rows = T + (cfg.prefix_len
                      if with_emb and cfg.family == "vlm" else 0)

        if native is None:
            native = self.paged_native       # static: picked at trace time

        def _chunk(params, tokens, emb, pages, state, lens, slot, bt_row,
                   n_valid):
            setattr(self, counter,           # runs at trace time only
                    getattr(self, counter) + 1)
            start = lens[slot]
            # a FIRST chunk (start == 0, set by reset_len at admission)
            # must see freshly initialized per-slot state, not the slot's
            # previous tenant's conv/SSD/cross leftovers
            slot_state = [jnp.where(start > 0, s[:, slot],
                                    jnp.zeros_like(s[:, slot]))[:, None]
                          for s in state]
            batch = {"tokens": tokens}
            if emb is not None:
                batch["embeddings"] = emb
            if native:
                cache = arena.assemble(pages, slot_state, start[None])
                logits, new_cache = api.prefill_chunk_paged(
                    params, cfg, batch, cache, bt_row[None],
                    chunk_len=n_valid, block_size=arena.block_size,
                    impl=impl)
                new_pages, new_state = arena.disassemble(new_cache)
                new_len = jnp.asarray(kvcache.lens(new_cache),
                                      jnp.int32).reshape(-1)[0]
            else:
                dense = arena.dense_view(pages, bt_row[None])
                cache = arena.assemble(dense, slot_state, start[None])
                logits, new_cache = api.prefill_chunk(params, cfg, batch,
                                                      cache,
                                                      chunk_len=n_valid,
                                                      impl=impl)
                new_dense, new_state = arena.disassemble(new_cache)
                new_len = jnp.asarray(kvcache.lens(new_cache),
                                      jnp.int32).reshape(-1)[0]
                new_pages = arena.append_rows(
                    pages, new_dense, start[None], jnp.ones((1,), bool),
                    bt_row[None], n_tokens=n_rows,
                    valid_tokens=(new_len - start)[None])
            state = [s.at[:, slot].set(ns[:, 0].astype(s.dtype))
                     for s, ns in zip(state, new_state)]
            return logits, new_pages, state, lens.at[slot].set(new_len)

        return jax.jit(_chunk, donate_argnums=arena._donate_argnums((3, 4,
                                                                     5)))

    def _run_chunk(self, arena: KVArena, s: _Slot, T: int) -> Any:
        """Advance one slot's prefill by one ``T``-bucket chunk; returns
        the chunk's logits (only the final chunk's are consumed)."""
        rem = len(s.req.tokens) - s.consumed
        n_valid = min(rem, T)
        toks = np.zeros((1, T), np.int32)
        toks[0, :n_valid] = s.req.tokens[s.consumed:s.consumed + n_valid]
        with_emb = (s.consumed == 0
                    and self.cfg.family in ("audio", "vlm"))
        emb = None
        if with_emb:
            emb = jnp.asarray(np.asarray(s.req.extras["embeddings"])[None])
        fn = self._chunk_fns.get((T, with_emb))
        if fn is None:
            fn = self._build_chunk_fn(arena, T, with_emb)
            self._chunk_fns[(T, with_emb)] = fn
        # copy-on-write before the chunk lands: a prefix-cache hit into a
        # PARTIAL block shares it read-only; our first write past the
        # divergence point forks a private copy (other slots and the
        # frozen index entry keep reading the original)
        copied = arena.ensure_writable(s.slot_id, s.consumed, n_valid)
        if copied:
            self.admission_copy_bytes += (copied * arena.block_size
                                          * arena.token_bytes)
        logits, arena.pages, arena.state, arena.lens = fn(
            self.params, jnp.asarray(toks), emb, arena.pages, arena.state,
            arena.lens, jnp.asarray(s.slot_id, jnp.int32),
            jnp.asarray(arena._block_tables[s.slot_id], jnp.int32),
            jnp.asarray(n_valid, jnp.int32))
        s.consumed += n_valid
        self.prefill_chunk_calls += 1
        self.prefill_tokens_computed += n_valid
        rows = n_valid + (self.cfg.prefix_len
                          if with_emb and self.cfg.family == "vlm" else 0)
        # chunk writes are APPENDS of fresh rows, not admission copies:
        # account them separately so the zero-copy admission gate
        # (admission_copy_bytes) measures actual copies only
        self.chunk_write_bytes += arena.chunk_bytes(rows)
        return logits, n_valid, T

    def _prefill_chunks(self, state: _GroupState) -> int:
        """(b2) Advance in-progress prefills, at most ``prefill_chunk``
        tokens per group per step — the piggyback budget that bounds how
        long the step's fused decode can be delayed by prompt work.  The
        final chunk's logits seed the request's first sampled token."""
        if state.arena is None or not self.chunked_prefill:
            return 0
        tr = self.trace
        budget = self.prefill_chunk_tokens
        done_tokens = 0
        for s in state.slots:
            if budget <= 0:
                break
            while s.prefilling and budget > 0:
                T = self._pick_bucket(len(s.req.tokens) - s.consumed,
                                      budget)
                if T is None:        # budget can't afford another bucket
                    budget = 0
                    break
                t0 = time.perf_counter()
                ct0 = tr.clock() if tr.enabled else 0.0
                logits, n_valid, T = self._run_chunk(state.arena, s, T)
                budget -= T
                done_tokens += n_valid
                if tr.enabled:
                    tr.complete(self.obs_name, str(s.req.rid),
                                "prefill_chunk", ct0, tokens=n_valid,
                                bucket=T)
                if s.consumed >= len(s.req.tokens):
                    first = int(np.asarray(self._sample(
                        logits, [self._req_seed(s.req)],
                        [s.sample_idx], [0]))[0])
                    t1 = time.perf_counter()
                    s.prefill_s += t1 - t0
                    s.begin_decode(first, t1)
                    self._enable_spec(state, s)
                    self._spawn_forks(state, s, logits, t1)
                    if tr.enabled:
                        tid = str(s.req.rid)
                        tr.end(self.obs_name, tid,
                               tokens_computed=s.consumed)
                        tr.instant(self.obs_name, tid, "first_token")
                        tr.begin(self.obs_name, tid, "decode")
                    if state.prefix is not None:
                        # every FULL prompt block is now written and
                        # frozen: index the chain (hits extend existing
                        # paths; duplicated content keeps the first
                        # copy).  The partial tail block is deliberately
                        # NOT indexed yet — generation still appends into
                        # it, so freezing it now would make the owner COW
                        # its own tail; eviction indexes it once final.
                        state.prefix.insert(
                            s.req.tokens,
                            state.arena._block_tables[s.slot_id],
                            include_partial=False)
                else:
                    jax.block_until_ready(logits)
                    s.prefill_s += time.perf_counter() - t0
        return done_tokens

    # -- speculative decoding: draft arena + fused verify ---------------
    def _spec_goal(self, s: _Slot) -> int:
        """Draft-cache rows a slot needs before it can run a spec round:
        the draft always lags the known tokens by exactly TWO rows, so
        every round's step 0 feeds ``known[-2]`` (catch-up, output
        discarded) and step 1 feeds ``emitted[-1]`` to propose the first
        draft — one uniform (k+1)-step round, no per-round shape
        variation, one compile."""
        return len(s.req.tokens) + len(s.emitted) - 2

    def _ensure_draft(self, state: _GroupState) -> KVArena:
        if state.draft is None:
            state.draft = KVArena(
                self.draft_cfg, self.draft_api.init_cache,
                capacity=self.plan.max_in_flight,
                max_seq_len=self.max_seq_len, block_size=self.block_size,
                kv_dtype="bf16")   # draft KV stays native precision: its
            #                        proposals are re-scored by the target
            #                        anyway, but int8 would change WHICH
            #                        tokens get proposed run-to-run
        return state.draft

    def _enable_spec(self, state: _GroupState, s: _Slot) -> None:
        """Arm speculation for a slot that just finished prefill: claim
        the MATCHING slot id in the group's draft arena (the two block
        tables stay aligned) and start the draft's catch-up chase —
        ``_draft_chunks`` prefill-chunks the known tokens into the draft
        cache while the slot keeps decoding normally; rounds start once
        the chase reaches the lag-1 goal.  Alloc failure degrades to
        plain decode (counted, never fatal).  Forks never speculate —
        their divergence is the point, and greedy drafts would collapse
        them."""
        if self.speculate_k <= 0 or s.sample_idx != 0 or s.done:
            return
        draft = self._ensure_draft(state)
        # draft rows run k past the known tokens mid-round
        total = min(len(s.req.tokens) + s.req.max_new_tokens
                    + self.speculate_k, draft.slot_tokens)
        if not draft.can_alloc(total):
            self.spec_degraded += 1
            return
        draft.alloc(total, slot=s.slot_id)
        draft.reset_len(s.slot_id)
        s.spec = True
        s.draft_len = 0

    def _draft_chunks(self, state: _GroupState) -> int:
        """Chase each speculating slot's draft cache toward its lag-1
        goal, at most one chunk budget per group per step (the same
        head-of-line bound as target prefill).  The goal moves +1 per
        normal decode step while the chase runs; the smallest chunk
        bucket is a whole block, so the chase always gains ground."""
        if state.draft is None:
            return 0
        draft = state.draft
        budget = self.prefill_chunk_tokens
        done_tokens = 0
        for s in state.slots:
            if budget <= 0:
                break
            if not s.spec or s.prefilling or s.done:
                continue
            goal = self._spec_goal(s)
            while s.draft_len < goal and budget > 0:
                T = self._pick_bucket(goal - s.draft_len, budget)
                if T is None:
                    budget = 0
                    break
                n_valid = min(goal - s.draft_len, T)
                known = np.concatenate(
                    [np.asarray(s.req.tokens, np.int32),
                     np.asarray(s.emitted, np.int32)])
                toks = np.zeros((1, T), np.int32)
                toks[0, :n_valid] = known[s.draft_len:s.draft_len + n_valid]
                fn = self._draft_chunk_fns.get(T)
                if fn is None:
                    fn = self._build_chunk_fn(
                        draft, T, False, api=self.draft_api,
                        cfg=self.draft_cfg, native=True,
                        counter="draft_prefill_traces")
                    self._draft_chunk_fns[T] = fn
                _, draft.pages, draft.state, draft.lens = fn(
                    self.draft_params, jnp.asarray(toks), None,
                    draft.pages, draft.state, draft.lens,
                    jnp.asarray(s.slot_id, jnp.int32),
                    jnp.asarray(draft._block_tables[s.slot_id], jnp.int32),
                    jnp.asarray(n_valid, jnp.int32))
                s.draft_len += n_valid
                budget -= T
                done_tokens += n_valid
                self.draft_prefill_tokens += n_valid
        return done_tokens

    def _build_verify_fn(self, arena: KVArena) -> Callable:
        """The ONE fused verify launch: score T = k+1 fed tokens per
        speculating slot against the target's paged cache
        (``api.verify_step_paged`` through the chunk-attention kernels
        with per-slot chunk lengths — 0 rows for non-speculating slots),
        then accept/reject with ``speculative_verify`` and commit each
        slot's length by its emit count, all inside one jit.  Compiles
        exactly once per service (``verify_traces``)."""
        api, cfg, impl = self.api, self.cfg, self._impl
        T = self.speculate_k + 1

        def _verify(params, tokens, dlogits, dtoks, pages, state, lens,
                    spec, seeds, sids, offs, block_tables, occ):
            self.verify_traces += 1          # runs at trace time only
            chunk_len = jnp.where(spec, T, 0).astype(jnp.int32)
            cache = arena.assemble(pages, state, lens)
            logits, new_cache = api.verify_step_paged(
                params, cfg, {"tokens": tokens}, cache, block_tables,
                chunk_len=chunk_len, block_size=arena.block_size,
                impl=impl)
            new_pages, new_state = arena.disassemble(new_cache)
            state2 = arena.merge_state(state, new_state, spec)
            out, n_emit = speculative_verify(
                logits, dlogits, dtoks, self._key, seeds, sids, offs,
                self.sampler, live=spec, occupancy=occ)
            new_lens = jnp.where(spec, lens + n_emit, lens)
            return out, n_emit, new_pages, state2, new_lens

        return jax.jit(_verify,
                       donate_argnums=arena._donate_argnums((4, 5, 6)))

    def _spec_round(self, state: _GroupState,
                    spec_slots: List[_Slot]) -> None:
        """One draft/verify round for every slot whose draft cache is
        caught up: k+1 fused DRAFT decode steps (step 0 replays
        ``known[-2]`` to close the lag, steps 1..k propose drafts from
        the STREAM_DRAFT counter streams), then ONE fused target verify
        launch commits up to k+1 tokens per slot.  After the round the
        draft rolls back to the new lag-1 goal (rejected proposals'
        rows become garbage past ``len``, overwritten by the next
        round)."""
        arena, draft = state.arena, state.draft
        cap = arena.capacity
        k = self.speculate_k
        tr = self.trace
        rt0 = tr.clock() if tr.enabled else 0.0
        live = np.zeros((cap,), bool)
        seeds = np.zeros((cap,), np.uint32)
        sids = np.zeros((cap,), np.uint32)
        offs = np.zeros((cap,), np.uint32)
        for s in spec_slots:
            sid = s.slot_id
            live[sid] = True
            seeds[sid] = np.uint32(self._req_seed(s.req) & 0xFFFFFFFF)
            sids[sid] = s.sample_idx
            offs[sid] = len(s.emitted)
        live_dev = jnp.asarray(live)
        if self._draft_decode_fn is None:
            self._draft_decode_fn = jax.jit(
                self._paged_decode_pure(draft, api=self.draft_api,
                                        cfg=self.draft_cfg, native=True,
                                        counter="draft_decode_traces"),
                donate_argnums=draft._donate_argnums((2, 3, 4)))
        drafts_host: List[np.ndarray] = []
        dlogit_steps: List[Any] = []
        for j in range(k + 1):
            tokens = np.zeros((cap,), np.int32)
            for s in spec_slots:
                if j == 0:
                    # catch-up row: the second-to-last known token (its
                    # output re-predicts a token we already have)
                    known_tail = (s.emitted[-2] if len(s.emitted) >= 2
                                  else s.req.tokens[-1])
                    tokens[s.slot_id] = known_tail
                elif j == 1:
                    tokens[s.slot_id] = s.emitted[-1]
                else:
                    tokens[s.slot_id] = drafts_host[j - 2][s.slot_id]
            logits, draft.pages, draft.state, draft.lens = \
                self._draft_decode_fn(
                    self.draft_params, jnp.asarray(tokens), draft.pages,
                    draft.state, draft.lens, live_dev,
                    draft.device_block_tables())
            self.draft_steps += 1
            if j >= 1:
                dlogit_steps.append(logits)
                d = self._sample(logits, seeds, sids, offs + (j - 1),
                                 live=live_dev, stream=STREAM_DRAFT)
                drafts_host.append(np.asarray(d))
        dlogits = jnp.stack(dlogit_steps, axis=1)          # (cap, k, V)
        dtoks = np.stack(drafts_host, axis=1).astype(np.int32)
        vtok = np.zeros((cap, k + 1), np.int32)
        for s in spec_slots:
            sid = s.slot_id
            vtok[sid, 0] = s.emitted[-1]
            vtok[sid, 1:] = dtoks[sid]
            # COW guard over the whole verify span (prefix-frozen tails,
            # fork-shared prompt blocks)
            start = len(s.req.tokens) + len(s.emitted) - 1
            copied = arena.ensure_writable(sid, start, k + 1)
            if copied:
                self.admission_copy_bytes += (copied * arena.block_size
                                              * arena.token_bytes)
        if self._verify_fn is None:
            self._verify_fn = self._build_verify_fn(arena)
        tv0 = tr.clock() if tr.enabled else 0.0
        out, n_emit, arena.pages, arena.state, arena.lens = \
            self._verify_fn(
                self.params, jnp.asarray(vtok), dlogits,
                jnp.asarray(dtoks), arena.pages, arena.state, arena.lens,
                live_dev, jnp.asarray(seeds), jnp.asarray(sids),
                jnp.asarray(offs), arena.device_block_tables(),
                arena.device_occupancy())
        self.verify_launches += 1
        if tr.enabled:
            tr.complete(self.obs_name, "engine", "verify", tv0,
                        slots=len(spec_slots), k=k)
        out_h, nem = np.asarray(out), np.asarray(n_emit)
        for s in spec_slots:
            sid = s.slot_id
            n = int(nem[sid])
            s.steps += 1
            for t in out_h[sid, :n]:
                # count only tokens the request actually keeps: verify can
                # commit past max_new/EOS, but those rows are garbage the
                # eviction discards, not accepted throughput
                self.accepted_tokens += 1
                s.push(int(t))
                if s.done:
                    break
            # roll the draft back to the NEW lag-1 goal: everything past
            # it is a rejected proposal's row (or the accepted ones we'll
            # re-feed), garbage past len by construction
            dl = self._spec_goal(s)
            draft.set_len(sid, dl)
            s.draft_len = dl
            if tr.enabled:
                tr.complete(self.obs_name, str(s.req.rid), "spec_round",
                            rt0, k=k, accepted=n)

    # -- n>1 parallel sampling: refcounted prompt-block forks -----------
    def _spawn_forks(self, state: _GroupState, s: _Slot, logits,
                     wall: float) -> None:
        """Fork ``n_samples - 1`` sibling slots off a primary that just
        finished prefill: each fork allocs with ``shared=`` the primary's
        prompt blocks (refcount bumps, ZERO prefill compute or copies),
        draws its own first token from the same final-chunk logits on its
        own ``sample_idx`` counter stream, and diverges from the shared
        tail block by copy-on-write on its first append.  Slot or block
        pressure spawns fewer than asked (counted as shortfall) — the
        primary always runs."""
        if s.sample_idx != 0:
            return
        asked = int(getattr(s.req, "n_samples", 1)) - 1
        want = min(asked + 1, self.n_samples_cap) - 1
        if want <= 0:
            # shortfall counts every sibling the caller asked for but the
            # category cap / batch budget denied, not just alloc failures
            self.fork_shortfall += max(0, asked)
            return
        arena = state.arena
        P = len(s.req.tokens) + self._extra_cache_tokens()
        total = P + s.req.max_new_tokens
        shared = list(arena._block_tables[s.slot_id][:arena.blocks_for(P)])
        seed = self._req_seed(s.req)
        first = np.asarray(self._sample(
            jnp.broadcast_to(logits.reshape(1, -1),
                             (want, logits.shape[-1])),
            [seed] * want, list(range(1, want + 1)), [0] * want))
        spawned = 0
        for i in range(want):
            if (state.live >= self.plan.bs
                    or not arena.can_alloc(total, shared=shared)):
                break
            sid = arena.alloc(total, shared=shared)
            arena.set_len(sid, P)
            fork = _Slot(s.req, None, prefill_s=s.prefill_s,
                         admit_wall=s.admit_wall,
                         admitted_s=s.admitted_s, slot_id=sid)
            fork.consumed = len(s.req.tokens)
            fork.sample_idx = i + 1
            fork.begin_decode(int(first[i]), wall)
            state.slots.append(fork)
            spawned += 1
            if self.trace.enabled:
                # forks live on their own "rid.sample" lane carrying only
                # a decode span: zero prefill is the point
                ftid = self._slot_tid(fork)
                self.trace.begin(self.obs_name, ftid, "decode", fork=True)
                self.trace.instant(self.obs_name, ftid, "first_token")
        self.forks_spawned += spawned
        self.fork_shortfall += asked - spawned
        if spawned:
            self._sibling_refs[s.req.rid] = spawned + 1

    # -- fused decode: paged arena path ---------------------------------
    def _paged_decode_pure(self, arena: KVArena,
                           api: Optional[ModelApi] = None,
                           cfg: Optional[ModelConfig] = None,
                           native: Optional[bool] = None,
                           counter: str = "decode_traces") -> Callable:
        """The fused decode step as a PURE function of
        ``(params, tokens, pages, state, lens, live, block_tables)`` ->
        ``(logits, pages, state, lens)`` — what ``_build_paged_decode_fn``
        jits locally and what a launcher's ``paged_step_builder`` wraps in
        ``pjit`` with mesh shardings for MP-sharded paged decode.

        ``api``/``cfg``/``native``/``counter`` default to the TARGET
        model; the speculative path passes the DRAFT model's to build the
        fused draft step over the draft arena (compiles counted under
        ``draft_decode_traces``)."""
        api = self.api if api is None else api
        cfg = self.cfg if cfg is None else cfg
        impl = self._impl
        if native is None:
            native = self.paged_native       # static: picked at trace time

        def _step(params, tokens, pages, state, lens, live, block_tables):
            setattr(self, counter,           # runs at trace time only
                    getattr(self, counter) + 1)
            if native:
                # paged leaves stay PAGE POOLS: the family's attention
                # streams K/V through the block table in place and writes
                # only each live slot's new row — no dense view, no
                # re-scatter
                cache = arena.assemble(pages, state, lens)
                logits, new_cache = api.decode_step_paged(
                    params, cfg, tokens, cache, block_tables, live,
                    block_size=arena.block_size, impl=impl)
                new_pages, new_state = arena.disassemble(new_cache)
            else:
                dense = arena.dense_view(pages, block_tables)
                cache = arena.assemble(dense, state, lens)
                logits, new_cache = api.decode_step(params, cfg, tokens,
                                                    cache, impl=impl)
                new_dense, new_state = arena.disassemble(new_cache)
                new_pages = arena.append_rows(pages, new_dense, lens, live,
                                              block_tables)
            state = arena.merge_state(state, new_state, live)
            lens = jnp.where(live, lens + 1, lens)
            return logits, new_pages, state, lens

        return _step

    def _build_paged_decode_fn(self, arena: KVArena):
        if self.paged_step_builder is not None:
            return self.paged_step_builder(self, arena)
        # donate the arena buffers (args 2..4) so XLA appends in place
        # instead of re-materializing the page pool every decode step
        return jax.jit(self._paged_decode_pure(arena),
                       donate_argnums=arena._donate_argnums((2, 3, 4)))

    def decode_cost_analysis(self, group: int = 0) -> Dict[str, Any]:
        """XLA cost analysis of the compiled fused decode step at the
        group's CURRENT arena shapes — the zero-gather regression surface
        (``BENCH_decode.json`` and the HLO tests assert the paged-native
        step's bytes accessed beat the dense-gather oracle's).  Uses a
        throwaway lowering so the serving fast path's jit cache and the
        ``decode_traces`` compile counter stay untouched."""
        state = self.groups[group]
        arena = self._ensure_arena(state)
        traces0, ptraces0 = self.decode_traces, self.prefill_traces
        try:
            lowered = jax.jit(self._paged_decode_pure(arena)).lower(
                self.params, jnp.zeros((arena.capacity,), jnp.int32),
                arena.pages, arena.state, arena.lens,
                jnp.ones((arena.capacity,), bool),
                arena.device_block_tables())
            cost = lowered.compile().cost_analysis()
        finally:
            self.decode_traces, self.prefill_traces = traces0, ptraces0
        if isinstance(cost, (list, tuple)):   # jax version compat
            cost = cost[0]
        return dict(cost)

    def _decode_group_paged(self, state: _GroupState) -> None:
        arena = state.arena
        cap = arena.capacity
        k = self.speculate_k
        tokens = np.zeros((cap,), np.int32)
        live = np.zeros((cap,), bool)
        seeds = np.zeros((cap,), np.uint32)
        sids = np.zeros((cap,), np.uint32)
        offs = np.zeros((cap,), np.uint32)
        spec_round: List[_Slot] = []
        for s in state.slots:
            if s.done or s.prefilling:
                continue
            if s.spec:
                if (len(s.req.tokens) + len(s.emitted) + k
                        > arena.slot_tokens):
                    # tail of generation: a full round would write past
                    # the slot's table width — finish with plain decode
                    # (greedy tokens are identical either way)
                    state.draft.free(s.slot_id)
                    s.spec = False
                    s.draft_len = 0
                    self.spec_degraded += 1
                elif s.draft_len >= self._spec_goal(s):
                    spec_round.append(s)
                    continue
                # else: draft still chasing — decode normally this step
            sid = s.slot_id
            tokens[sid] = s.emitted[-1]
            live[sid] = True
            seeds[sid] = np.uint32(self._req_seed(s.req) & 0xFFFFFFFF)
            sids[sid] = s.sample_idx
            offs[sid] = len(s.emitted)
            # the append position can sit inside a block the prefix index
            # froze (this slot's own registered partial tail, a
            # block-aligned shared prefix whose last block the generation
            # now extends) or one an n>1 sibling still shares: COW first.
            # The arena's cheap guard makes this free when nothing in the
            # pool is shared, so the call is unconditional.
            pos = (len(s.req.tokens) + self._extra_cache_tokens()
                   + len(s.emitted) - 1)
            copied = arena.ensure_writable(sid, pos, 1)
            if copied:
                self.admission_copy_bytes += (
                    copied * arena.block_size * arena.token_bytes)
        if live.any():
            if self._paged_decode_fn is None:
                self._paged_decode_fn = self._build_paged_decode_fn(arena)
            live_dev = jnp.asarray(live)
            logits, arena.pages, arena.state, arena.lens = \
                self._paged_decode_fn(
                    self.params, jnp.asarray(tokens), arena.pages,
                    arena.state, arena.lens, live_dev,
                    arena.device_block_tables())
            tr = self.trace
            ts0 = tr.clock() if tr.enabled else 0.0
            toks = np.asarray(self._sample(
                logits, seeds, sids, offs, live=live_dev,
                occupancy=arena.device_occupancy()))
            if tr.enabled:
                tr.complete(self.obs_name, "engine", "sample", ts0,
                            live=int(live.sum()))
            self.decode_steps += 1
            for slot in state.slots:
                if slot.done or slot.prefilling or not live[slot.slot_id]:
                    continue
                slot.steps += 1
                slot.push(int(toks[slot.slot_id]))
        if spec_round:
            self._spec_round(state, spec_round)

    # -- fused decode: dense (merge/select) path ------------------------
    def _decode_group_dense(self, state: _GroupState) -> None:
        live = np.array([not s.done for s in state.slots])
        if not live.any():
            return               # everything awaits eviction
        cur = jnp.asarray([s.emitted[-1] if not s.done else 0
                           for s in state.slots], jnp.int32)
        logits, state.cache = self.decode_fn(self.params, cur, state.cache)
        toks = np.asarray(self._sample(
            logits, [self._req_seed(s.req) for s in state.slots],
            [s.sample_idx for s in state.slots],
            [len(s.emitted) for s in state.slots],
            live=jnp.asarray(live)))
        self.decode_steps += 1
        for i, slot in enumerate(state.slots):
            if slot.done:
                continue
            slot.steps += 1
            slot.push(int(toks[i]))

    def _decode_group(self, state: _GroupState) -> None:
        """(c) One fused decode step over every occupied slot."""
        if not state.slots:
            return
        if state.arena is not None:
            self._decode_group_paged(state)
        else:
            self._decode_group_dense(state)

    # -- prefix-cache telemetry (summed across DP groups) ---------------
    def _prefix_totals(self):
        lk = ht = hits = ev = cow = 0
        for g in self.groups.values():
            if g.prefix is not None:
                lk += g.prefix.lookups
                hits += g.prefix.hits
                ht += g.prefix.hit_tokens
            if g.arena is not None:
                ev += g.arena.cached_evictions
                cow += g.arena.cow_copies
        return lk, hits, ht, ev, cow

    @property
    def prefix_hit_tokens(self) -> int:
        return self._prefix_totals()[2]

    @property
    def prefix_hits(self) -> int:
        return self._prefix_totals()[1]

    @property
    def prefix_evictions(self) -> int:
        return self._prefix_totals()[3]

    @property
    def prefix_cow_copies(self) -> int:
        return self._prefix_totals()[4]

    def _phase_mark(self, name: str, start: float, **args) -> float:
        """Emit one engine-phase complete event ending NOW and return
        that end — the next phase's start (contiguous phase track)."""
        end = self.trace.clock()
        self.trace.complete(self.obs_name, "engine", name, start, end,
                            **args)
        return end

    def _step_continuous(self, now: float, max_wait_s: float) -> StepStats:
        tr = self.trace
        t_phase = step_t0 = tr.clock() if tr.enabled else 0.0
        copy0, whole0 = self.admission_copy_bytes, self.whole_cache_copies
        chunkw0 = self.chunk_write_bytes
        steps0, one0 = self.decode_steps, self.oneshot_prefills
        draft0, ver0 = self.draft_steps, self.verify_launches
        acc0, deg0 = self.accepted_tokens, self.spec_degraded
        fk0, fs0 = self.forks_spawned, self.fork_shortfall
        pfx0 = self._prefix_totals()
        moe0 = self._moe_stats.dropped if self._moe_stats else 0.0
        results: List[GenerationResult] = []
        for group, state in self.groups.items():
            results.extend(self._evict(group, state, now))
        if tr.enabled:
            t_phase = self._phase_mark("evict", t_phase,
                                       evicted=len(results))
        # admission control (inert under the "fifo" policy): learn the
        # caller's clock, shed with verdicts, order by slack, then park a
        # victim if the urgent head can't wait — all BEFORE compose so
        # the freed slot goes to the strictest deadline
        ctrl = self.admission
        rejected: List[AdmissionReject] = []
        preempt0, resume0 = ctrl.preemptions, ctrl.resumes
        if ctrl.active:
            ctrl.note_step(now)
            ctrl.order(now)          # slack order FIRST: shed walks it
            rejected = self._shed_rejected(now)
            self._maybe_preempt(now)
            if tr.enabled:
                t_phase = self._phase_mark(
                    "preempt", t_phase, shed=len(rejected),
                    parked=ctrl.preemptions - preempt0)
        admitted = self._admit(now, max_wait_s)
        if tr.enabled:
            t_phase = self._phase_mark("admit", t_phase, admitted=admitted)
        chunk_tokens = 0
        for state in self.groups.values():
            n = self._prefill_chunks(state)
            chunk_tokens += n
            self._draft_chunks(state)
            if tr.enabled:
                t_phase = self._phase_mark("chunk", t_phase, tokens=n)
            self._decode_group(state)
            if tr.enabled:
                t_phase = self._phase_mark("fused_decode", t_phase)
        pfx1 = self._prefix_totals()
        if tr.enabled:
            tr.complete(self.obs_name, "engine", "step", step_t0,
                        admitted=admitted, evicted=len(results),
                        in_flight=self.in_flight(),
                        pending=self.pending())
        verdict_count = lambda v: sum(1 for r in rejected
                                      if r.verdict is v)
        return StepStats(
            results=results, now=now, admitted=admitted,
            evicted=len(results), in_flight=self.in_flight(),
            pending=self.pending(),
            queue_time_s=self.queue_time_estimate(),
            admission_copy_bytes=self.admission_copy_bytes - copy0,
            chunk_write_bytes=self.chunk_write_bytes - chunkw0,
            whole_cache_copies=self.whole_cache_copies - whole0,
            decode_steps=self.decode_steps - steps0,
            prefill_chunk_tokens=chunk_tokens,
            oneshot_prefills=self.oneshot_prefills - one0,
            prefix_lookups=pfx1[0] - pfx0[0],
            prefix_hits=pfx1[1] - pfx0[1],
            prefix_hit_tokens=pfx1[2] - pfx0[2],
            prefix_evicted_blocks=pfx1[3] - pfx0[3],
            prefix_cow_blocks=pfx1[4] - pfx0[4],
            moe_dropped_tokens=((self._moe_stats.dropped - moe0)
                                if self._moe_stats else 0.0),
            rejected=rejected,
            deadline_missed=verdict_count(Outcome.DEADLINE_MISSED),
            congestion_rejects=verdict_count(Outcome.CONGESTION),
            offload_verdicts=verdict_count(Outcome.OFFLOAD),
            failed_rejects=verdict_count(Outcome.FAILED),
            evacuated=self._take_evacuated(),
            preempted=ctrl.preemptions - preempt0,
            resumed=ctrl.resumes - resume0,
            parked=len(ctrl.parked),
            draft_steps=self.draft_steps - draft0,
            verify_launches=self.verify_launches - ver0,
            accepted_tokens=self.accepted_tokens - acc0,
            spec_slots=sum(1 for g in self.groups.values()
                           for s in g.slots if s.spec and not s.done),
            forks_spawned=self.forks_spawned - fk0,
            fork_shortfall=self.fork_shortfall - fs0,
            spec_degraded=self.spec_degraded - deg0)

    # ------------------------------------------------------------------
    # sync mode: run-to-completion batches (the pre-slot baseline)
    # ------------------------------------------------------------------
    def run_batch(self, composed: ComposedBatch, *,
                  now: float = 0.0) -> List[GenerationResult]:
        reqs = [item.payload for item in composed.items]
        group = self.router.route(session=reqs[0].stream)
        toks, lens = self._pad_prompts(reqs)
        max_new = max(r.max_new_tokens for r in reqs)
        cache_size = int(toks.shape[1] + max_new)

        t0 = time.perf_counter()
        batch = self._build_batch(reqs, toks)
        logits, cache = self.prefill_fn(self.params, batch, cache_size)
        logits = jax.block_until_ready(logits)
        t1 = time.perf_counter()
        self.oneshot_prefills += len(reqs)
        self.prefill_tokens_computed += sum(len(r.tokens) for r in reqs)

        outs = []
        seeds = [self._req_seed(r) for r in reqs]
        zeros = [0] * len(reqs)
        cur = self._sample(logits, seeds, zeros, zeros)
        outs.append(np.asarray(cur))
        for i in range(max_new - 1):
            logits, cache = self.decode_fn(self.params, cur, cache)
            cur = self._sample(logits, seeds, zeros,
                               [i + 1] * len(reqs))
            outs.append(np.asarray(cur))
            self.decode_steps += 1
        jax.block_until_ready(cur)
        t2 = time.perf_counter()

        gen = np.stack(outs, axis=1)  # (B, max_new)
        results = []
        for i, r in enumerate(reqs):
            # sync mode charges the batch-wide decode time to every member
            # (the very distortion the slot path fixes)
            results.append(GenerationResult(
                rid=r.rid, tokens=gen[i, :r.max_new_tokens],
                prefill_s=t1 - t0, decode_s=t2 - t1, group=group,
                admitted_s=now, finished_s=now,
                decode_steps=max_new - 1))
            self._finish_request(r, group)
        return results

    def _step_sync(self, now: float, max_wait_s: float) -> StepStats:
        steps0 = self.decode_steps
        composed = self.composer.compose(now=now, max_wait_s=max_wait_s)
        results = ([] if composed is None
                   else self.run_batch(composed, now=now))
        return StepStats(results=results, now=now, admitted=len(results),
                         evicted=len(results), in_flight=self.in_flight(),
                         pending=self.pending(),
                         queue_time_s=self.queue_time_estimate(),
                         decode_steps=self.decode_steps - steps0)

    # ------------------------------------------------------------------
    def step(self, now: float = 0.0,
             max_wait_s: float = float("inf")) -> StepStats:
        """Advance the data plane by one scheduling round and report its
        telemetry.  Continuous mode: evict / admit / one fused decode
        step.  Sync mode: compose one batch (BS or MF semantics) and run
        it to completion."""
        stats = (self._step_sync(now, max_wait_s) if self.mode == "sync"
                 else self._step_continuous(now, max_wait_s))
        if self.metrics is not None:
            self.metrics.observe_step(self.obs_name, stats, runtime=self)
        return stats

    def drain(self, now: float = 0.0,
              max_wait_s: float = 0.0) -> List[GenerationResult]:
        """Step until queue and slots are empty; returns all results."""
        out: List[GenerationResult] = []
        while self.pending() or self.in_flight():
            before = (self.pending(), self.in_flight(), self.decode_steps,
                      self.prefill_chunk_calls, self.verify_launches,
                      self.draft_prefill_tokens)
            stats = self.step(now=now, max_wait_s=max_wait_s)
            out.extend(stats.results)
            if (self.pending(), self.in_flight(), self.decode_steps,
                    self.prefill_chunk_calls, self.verify_launches,
                    self.draft_prefill_tokens) == before \
                    and not stats.results:
                break            # no progress possible (e.g. empty compose)
        return out


class EparaServingEngine:
    """Multi-service front door: submits requests to ServiceRuntimes by
    service name.  Placement/offload decisions come from the control plane
    (see examples/serve_cluster.py); this class is the data plane.  The
    per-service ``StepStats`` of the latest round are kept in
    ``last_stats`` for the handler's queue-time feedback."""

    def __init__(self):
        self.runtimes: Dict[str, ServiceRuntime] = {}
        self.last_stats: Dict[str, StepStats] = {}
        self._results: List[GenerationResult] = []

    def deploy(self, name: str, runtime: ServiceRuntime) -> None:
        if not runtime._obs_named:
            # observability labels follow the DEPLOYED name (two services
            # can share a ModelConfig), unless the caller pinned one
            runtime.obs_name = name
        self.runtimes[name] = runtime

    def submit(self, service: str, req: GenerationRequest,
               now: float = 0.0) -> None:
        self.runtimes[service].submit(req, now)

    def step(self, now: float = 0.0,
             max_wait_s: float = 0.0) -> List[GenerationResult]:
        """One scheduling round across every deployed runtime."""
        out: List[GenerationResult] = []
        for name, rt in self.runtimes.items():
            stats = rt.step(now=now, max_wait_s=max_wait_s)
            self.last_stats[name] = stats
            out.extend(stats.results)
        self._results.extend(out)
        return out

    def drain(self, now: float = 0.0) -> List[GenerationResult]:
        return self.serve_until_idle(now=now)

    def serve_until_idle(self, now: float = 0.0, max_wait_s: float = 0.0,
                         on_stats: Optional[Callable] = None,
                         clock: Optional[Callable[[], float]] = None
                         ) -> List[GenerationResult]:
        """Step every runtime round-robin until no runtime can make
        progress, invoking ``on_stats(service, stats)`` after each round —
        the hook the launchers use to feed ``StepStats.queue_time_s`` back
        into the control plane's handler state.  ``clock`` (when given)
        supplies each round's ``now`` — a live clock is what makes the
        admission controller's deadlines bite (a frozen ``now`` never
        expires anything)."""
        out: List[GenerationResult] = []
        progress = True
        while progress:
            progress = False
            for name, rt in self.runtimes.items():
                if not (rt.pending() or rt.in_flight()):
                    continue
                stats = rt.step(now=clock() if clock is not None else now,
                                max_wait_s=max_wait_s)
                self.last_stats[name] = stats
                out.extend(stats.results)
                if on_stats is not None:
                    on_stats(name, stats)
                if (stats.results or stats.admitted or stats.decode_steps
                        or stats.prefill_chunk_tokens or stats.rejected
                        or stats.verify_launches or stats.draft_steps):
                    progress = True
        self._results.extend(out)
        return out
