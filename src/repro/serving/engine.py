"""Live serving engine: batched prefill + decode driven by an EPARA
ParallelPlan.

``ServiceRuntime`` owns one service's params and its DP replica groups;
each group runs batch-synchronous generation (prefill the composed batch,
decode until done).  Request-level DP round-robins composed batches across
groups (sticky for stateful archs).  The same engine object backs the CPU
examples (reduced configs) and, via pjit'd step functions passed in by the
launcher, the mesh deployment.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.allocator import DPGroupRouter, ParallelPlan
from repro.models.config import ModelConfig
from repro.models.registry import ModelApi, model_api

from .batching import BSComposer, ComposedBatch, MFComposer, QueuedItem, \
    make_composer
from .sampler import SamplerConfig, sample


@dataclasses.dataclass
class GenerationRequest:
    rid: int
    tokens: np.ndarray               # prompt (L,) int32
    max_new_tokens: int = 16
    stream: int = 0
    extras: Optional[Dict[str, Any]] = None   # e.g. image/frame embeddings
    submitted_s: float = 0.0


@dataclasses.dataclass
class GenerationResult:
    rid: int
    tokens: np.ndarray               # generated ids (n,)
    prefill_s: float
    decode_s: float
    group: int


class ServiceRuntime:
    """One deployed service: params + plan + DP groups."""

    def __init__(self, cfg: ModelConfig, params, plan: ParallelPlan, *,
                 prefill_fn: Optional[Callable] = None,
                 decode_fn: Optional[Callable] = None,
                 sampler: SamplerConfig = SamplerConfig(), seed: int = 0,
                 impl: Optional[str] = None):
        self.cfg = cfg
        self.params = params
        self.plan = plan
        self.api: ModelApi = model_api(cfg)
        self.router = DPGroupRouter(plan)
        self.composer = make_composer(plan)
        self.sampler = sampler
        self._key = jax.random.PRNGKey(seed)
        impl = impl
        api = self.api

        if prefill_fn is None:
            prefill_fn = jax.jit(
                lambda p, b, cs: api.prefill(p, cfg, b, cache_size=cs,
                                             impl=impl),
                static_argnums=(2,))
        if decode_fn is None:
            decode_fn = jax.jit(
                lambda p, t, c: api.decode_step(p, cfg, t, c, impl=impl))
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn

    # -- queue ------------------------------------------------------------
    def submit(self, req: GenerationRequest, now: float = 0.0) -> None:
        self.composer.add(QueuedItem(payload=req, stream=req.stream,
                                     enqueued_s=now, rid=req.rid))

    def pending(self) -> int:
        return len(self.composer)

    # -- execution ----------------------------------------------------------
    def _pad_prompts(self, reqs: Sequence[GenerationRequest]):
        L = max(len(r.tokens) for r in reqs)
        toks = np.zeros((len(reqs), L), np.int32)
        lens = np.zeros((len(reqs),), np.int32)
        for i, r in enumerate(reqs):
            toks[i, L - len(r.tokens):] = r.tokens   # left-pad
            lens[i] = len(r.tokens)
        return jnp.asarray(toks), lens

    def _build_batch(self, reqs: Sequence[GenerationRequest], toks):
        batch: Dict[str, Any] = {"tokens": toks}
        if self.cfg.family in ("audio", "vlm"):
            embs = [r.extras["embeddings"] for r in reqs]
            batch["embeddings"] = jnp.asarray(np.stack(embs))
        return batch

    def run_batch(self, composed: ComposedBatch, *,
                  now: float = 0.0) -> List[GenerationResult]:
        reqs = [item.payload for item in composed.items]
        group = self.router.route(session=reqs[0].stream)
        toks, lens = self._pad_prompts(reqs)
        max_new = max(r.max_new_tokens for r in reqs)
        cache_size = int(toks.shape[1] + max_new)

        t0 = time.perf_counter()
        batch = self._build_batch(reqs, toks)
        logits, cache = self.prefill_fn(self.params, batch, cache_size)
        logits = jax.block_until_ready(logits)
        t1 = time.perf_counter()

        outs = []
        cur = self._sample(logits)
        outs.append(np.asarray(cur))
        for _ in range(max_new - 1):
            logits, cache = self.decode_fn(self.params, cur, cache)
            cur = self._sample(logits)
            outs.append(np.asarray(cur))
        jax.block_until_ready(cur)
        t2 = time.perf_counter()

        gen = np.stack(outs, axis=1)  # (B, max_new)
        results = []
        for i, r in enumerate(reqs):
            results.append(GenerationResult(
                rid=r.rid, tokens=gen[i, :r.max_new_tokens],
                prefill_s=t1 - t0, decode_s=t2 - t1, group=group))
        return results

    def _sample(self, logits):
        self._key, sub = jax.random.split(self._key)
        return sample(logits, sub, self.sampler)

    def step(self, now: float = 0.0,
             max_wait_s: float = float("inf")) -> List[GenerationResult]:
        """Compose one batch (BS or MF semantics) and run it."""
        if isinstance(self.composer, MFComposer):
            composed = self.composer.compose(now=now, max_wait_s=max_wait_s)
        else:
            composed = self.composer.compose()
        if composed is None:
            return []
        return self.run_batch(composed, now=now)


class EparaServingEngine:
    """Multi-service front door: submits requests to ServiceRuntimes by
    service name.  Placement/offload decisions come from the control plane
    (see examples/serve_cluster.py); this class is the data plane."""

    def __init__(self):
        self.runtimes: Dict[str, ServiceRuntime] = {}
        self._results: List[GenerationResult] = []

    def deploy(self, name: str, runtime: ServiceRuntime) -> None:
        self.runtimes[name] = runtime

    def submit(self, service: str, req: GenerationRequest,
               now: float = 0.0) -> None:
        self.runtimes[service].submit(req, now)

    def drain(self, now: float = 0.0) -> List[GenerationResult]:
        out: List[GenerationResult] = []
        for rt in self.runtimes.values():
            while rt.pending():
                res = rt.step(now=now, max_wait_s=0.0)
                if not res:
                    break
                out.extend(res)
        self._results.extend(out)
        return out
