"""Radix prefix cache: shared-prefix KV reuse over the paged arena.

EPARA's frequency-sensitive category is dominated by periodic requests
repeating the same system/prompt prefix (sensor pipelines, templated LLM
calls); re-prefilling that prefix on every admission wastes the dominant
share of prompt compute.  ``RadixPrefixCache`` indexes the arena's
physical blocks by their *token content* so a new admission can stitch
the longest cached prefix straight into its block table and start chunked
prefill after the hit boundary.

Structure
---------
* **Radix tree keyed on block-aligned token runs.**  Each node is one
  FULL block of ``block_size`` prompt tokens; a node's children are keyed
  by the next block's token tuple (the dict hash is the "block-aligned
  token hash"; the stored tuple disambiguates collisions exactly).  A
  path root→node therefore spells a block-aligned prompt prefix and
  carries the physical block ids holding its KV.
* **Partial tails.**  A prompt's final sub-block run (``len % block_size``
  tokens) is indexed on its deepest full-block node.  A lookup may match
  into a partial tail; the sharer then *must* copy-on-write that block
  before its own writes land in it (``KVArena.ensure_writable``), because
  other slots — or the frozen cache entry itself — still read it.  This
  is the divergence-point COW: two prompts that agree mid-block share the
  block read-only and fork private copies the moment they diverge.
* **Lifetime.**  The cache never owns device memory: blocks belong to the
  arena.  ``insert`` registers live slots' prompt blocks
  (``arena.register`` freezes them — any writer COWs); when the last slot
  referencing a block dies the block parks on the arena's LRU of
  idle-but-cached blocks, and the allocator reclaims LRU-first under
  pressure, calling back ``_on_evict`` so the index drops the evicted
  block's node *and its whole subtree* (a chain with a missing interior
  block is unreachable and would pin memory).

Safety
------
Only cache layouts whose paged content is a pure function of the prompt
token ids may share blocks: families with per-slot state leaves (SSM /
hybrid conv state, enc-dec cross-KV) or non-token inputs (VLM image
prefix, audio embeddings) are rejected by the engine's gate.  Blocks
holding *generated* tokens are never indexed.  A full-prompt hit is
capped at ``len(prompt) - 1`` tokens so at least one token is always
computed — the final chunk's logits seed the first sampled token.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

TokenRun = Tuple[int, ...]


@dataclasses.dataclass
class PrefixHit:
    """Result of one lookup: the physical blocks to stitch into the new
    slot's table (full-block matches first, then at most one partial-tail
    block), and how many prompt tokens they cover."""
    blocks: List[int]
    tokens: int                  # hit boundary: cached prompt tokens
    full_blocks: int             # leading entries of ``blocks`` fully used
    partial_valid: int           # matched tokens inside the trailing
    #                              partial block (0 = no partial share)


class _Node:
    __slots__ = ("tokens", "block", "children", "partials", "parent")

    def __init__(self, tokens: TokenRun, block: int,
                 parent: Optional["_Node"]):
        self.tokens = tokens
        self.block = block                      # physical arena block
        self.parent = parent
        self.children: Dict[TokenRun, "_Node"] = {}
        self.partials: Dict[TokenRun, int] = {}  # tail tokens -> block


class RadixPrefixCache:
    """Prefix index for ONE ``KVArena`` (one DP replica group).

    The cache installs itself as the arena's ``evict_hook`` and sets the
    arena's idle-cache retention bound (the ``ParallelPlan.prefix_cache``
    category knob: latency plans bound retention, frequency plans retain
    aggressively)."""

    def __init__(self, arena, *, retention_blocks: Optional[int] = None):
        self.arena = arena
        self.block_size = int(arena.block_size)
        self.root = _Node((), -1, None)
        # physical block -> ("full", node) | ("partial", node, tail_key)
        self._by_block: Dict[int, tuple] = {}
        arena.evict_hook = self._on_evict
        arena.cache_retention = retention_blocks
        # telemetry
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.miss_tokens = 0
        self.inserted_blocks = 0

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._by_block)

    @staticmethod
    def _toks(tokens: Sequence[int]) -> TokenRun:
        return tuple(int(t) for t in tokens)

    # ------------------------------------------------------------------
    # lookup
    # ------------------------------------------------------------------
    def lookup(self, tokens: Sequence[int]) -> PrefixHit:
        """Longest cached prefix of ``tokens``, capped at
        ``len(tokens) - 1`` so the admission always computes at least the
        final prompt position (its logits seed sampling)."""
        bs = self.block_size
        toks = self._toks(tokens)
        cap = len(toks) - 1
        node, blocks, pos = self.root, [], 0
        while pos + bs <= cap:
            child = node.children.get(toks[pos:pos + bs])
            if child is None:
                break
            node = child
            blocks.append(child.block)
            pos += bs
        full = len(blocks)
        partial_valid = 0
        if pos < cap and node.partials:
            rest = toks[pos:]
            best_key, best_m = None, 0
            for key, blk in node.partials.items():
                m = 0
                for a, b in zip(key, rest):
                    if a != b:
                        break
                    m += 1
                m = min(m, cap - pos)
                if m > best_m:
                    best_key, best_m = key, m
            if best_key is not None:
                blocks.append(node.partials[best_key])
                partial_valid = best_m
        return PrefixHit(blocks=blocks, tokens=full * bs + partial_valid,
                         full_blocks=full, partial_valid=partial_valid)

    def record(self, hit: Optional[PrefixHit], prompt_len: int) -> None:
        """Telemetry for one ADMITTED request (lookups are pure so a
        requeued admission does not double-count)."""
        self.lookups += 1
        tokens = hit.tokens if hit is not None else 0
        if tokens > 0:
            self.hits += 1
            self.hit_tokens += tokens
        self.miss_tokens += prompt_len - tokens

    def note_resume(self, cache_tokens: int) -> None:
        """Telemetry for a preempted request resuming onto its parked
        blocks (serving/admission.py): the whole parked content — prompt
        AND generated KV — is served from resident blocks, the cache's
        best case.  Counted as a full hit so the reuse telemetry (and the
        engine's hit-rate EWMA inputs) reflect what parking saved."""
        self.lookups += 1
        self.hits += 1
        self.hit_tokens += cache_tokens

    # ------------------------------------------------------------------
    # insert
    # ------------------------------------------------------------------
    def insert(self, tokens: Sequence[int], block_row: "np.ndarray", *,
               include_partial: bool = True) -> int:
        """Index a fully prefilled prompt: walk/extend the radix chain for
        its full blocks and register its partial tail (if any) on the
        deepest node.  ``block_row`` is the slot's block-table row — entry
        ``i`` physically holds prompt tokens ``[i*bs, (i+1)*bs)``.  If a
        chain node already exists for some block's tokens (another prompt
        cached the same content first) the existing block wins and ours
        stays a private, uncached copy.  Returns newly indexed blocks.

        ``include_partial=False`` indexes only the full blocks: the engine
        uses it at prefill completion, when the owner's generation is
        still going to append INTO the partial tail block — registering it
        then would force the owner to COW its own tail.  The tail is
        indexed by a second insert at slot eviction, once its content is
        final."""
        bs = self.block_size
        toks = self._toks(tokens)
        node, pos, bi, added = self.root, 0, 0, 0
        while pos + bs <= len(toks):
            key = toks[pos:pos + bs]
            child = node.children.get(key)
            if child is None:
                blk = int(block_row[bi])
                child = _Node(key, blk, node)
                node.children[key] = child
                self._by_block[blk] = ("full", child)
                self.arena.register(blk)
                self.inserted_blocks += 1
                added += 1
            node = child
            pos += bs
            bi += 1
        rem = toks[pos:]
        if include_partial and rem and rem not in node.partials:
            blk = int(block_row[bi])
            if blk not in self._by_block:
                node.partials[rem] = blk
                self._by_block[blk] = ("partial", node, rem)
                self.arena.register(blk)
                self.inserted_blocks += 1
                added += 1
        return added

    # ------------------------------------------------------------------
    # eviction (arena -> cache callback)
    # ------------------------------------------------------------------
    def _on_evict(self, block: int) -> None:
        """The arena reclaimed ``block`` off the idle-cached LRU.  Drop
        its index entry; for a full-chain node the whole subtree below it
        becomes unreachable (its prefix chain is broken) and is
        unregistered too — live sharers keep their slots' references, the
        blocks simply stop being index-reachable."""
        ent = self._by_block.pop(block, None)
        if ent is None:
            return
        if ent[0] == "partial":
            _, node, key = ent
            node.partials.pop(key, None)
            return
        node = ent[1]
        if node.parent is not None:
            node.parent.children.pop(node.tokens, None)
        self._drop_subtree(node)

    def _drop_subtree(self, node: _Node) -> None:
        """Unregister every index entry below ``node`` (the node's own
        block was already detached by the arena's eviction sweep)."""
        stack = [node]
        while stack:
            n = stack.pop()
            for blk in n.partials.values():
                self._by_block.pop(blk, None)
                self.arena.unregister(blk)
            n.partials.clear()
            for child in n.children.values():
                self._by_block.pop(child.block, None)
                self.arena.unregister(child.block)
                stack.append(child)
            n.children.clear()
