"""Request-level fault recovery (§5.3.3 made operational).

``ClusterSupervisor`` is the drive loop the launchers previously
hand-rolled, grown a failure model: it owns a ledger of every submitted
request and guarantees the served-or-verdicted invariant — every rid
ends with either a ``GenerationResult`` or an ``AdmissionReject`` whose
verdict names why (``FAILED`` when every recovery avenue is exhausted).

Recovery mechanisms, in the order they fire:

* **timeout + backoff retries** — every placement arms a deadline-derived
  timeout (``RetryPolicy``); when it expires (dropped handoff, crashed or
  straggling host) the request re-routes to the next-best peer, excluding
  already-tried servers via the handler's own loop-prevention ``path``
  bookkeeping.  Attempts are bounded; exhaustion on a dead avenue is an
  explicit ``FAILED`` verdict, never a silent drop.
* **crash evacuation** — a crashed server's engines are stripped
  (``ServiceRuntime.evacuate``): queued, in-flight and parked requests
  come back out and resubmit to survivors.  Re-prefill rides the
  survivors' radix prefix cache; PR 8's counter-stream sampling makes the
  replayed tokens bit-identical to what the dead server would have
  produced, so failover is invisible in the output.
* **duplicate dedup** — a retried request may ALSO complete on its
  original host (straggler, not corpse).  Completions are deduplicated by
  ``(rid, sample)``; the first one wins, duplicates are counted.
* **degraded-mode routing** — the control plane's staleness bound
  (``core/handler.py``) stops peers from scoring a silent server's frozen
  digest; the ring heals around flagged servers and restarts rejoin via
  ``repair_server`` + re-publish.

The supervisor implements ``core/faults.py``'s ``FaultTarget`` surface,
so a deterministic ``FaultSpec`` replays the same adversary against it in
the chaos tests, the hypothesis suite and ``make bench-chaos``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.categories import Outcome, Request
from repro.core.faults import FaultEvent, FaultInjector, FaultSpec
from .admission import AdmissionReject


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Offload/handoff retry knobs.  The timeout for attempt ``a`` is
    ``base_timeout_s * backoff**a``, capped — when the request carries a
    deadline — at ``deadline_fraction`` of its remaining slack (never
    below ``base_timeout_s``: a nearly-expired request still gets one
    honest wait before its retry burns the last of the budget)."""
    base_timeout_s: float = 8.0
    backoff: float = 2.0
    max_attempts: int = 4
    deadline_fraction: float = 0.5

    def __post_init__(self):
        if self.base_timeout_s <= 0:
            raise ValueError(f"base_timeout_s must be positive, got "
                             f"{self.base_timeout_s}")
        if self.backoff < 1.0:
            raise ValueError(f"backoff must be >= 1, got {self.backoff}")
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got "
                             f"{self.max_attempts}")

    def timeout_s(self, attempt: int, deadline_s: float,
                  now: float) -> float:
        t = self.base_timeout_s * self.backoff ** max(0, attempt)
        if deadline_s and deadline_s < 1e9:
            slack = max(0.0, deadline_s - now)
            t = min(t, max(self.base_timeout_s,
                           slack * self.deadline_fraction))
        return t


@dataclasses.dataclass
class TrackedRequest:
    """Ledger entry: one submitted request and everything recovery needs
    to know about it."""
    req: Any                        # the GenerationRequest
    service: str
    origin: int                     # server the request arrived at
    server: int = -1                # current placement (-1 = none yet)
    attempts: int = 0
    timeout_at: float = float("inf")
    tried: set = dataclasses.field(default_factory=set)
    results: Dict[int, Any] = dataclasses.field(default_factory=dict)
    verdict: Optional[AdmissionReject] = None
    dropped: bool = False           # last handoff swallowed by the fault
    done: bool = False

    @property
    def open(self) -> bool:
        return not self.done


@dataclasses.dataclass
class ClusterReport:
    """What a supervised run produced, with the recovery telemetry."""
    results: List[Any] = dataclasses.field(default_factory=list)
    rejects: List[AdmissionReject] = dataclasses.field(default_factory=list)
    outcomes: Dict[str, int] = dataclasses.field(default_factory=dict)
    rounds: int = 0
    failovers: int = 0              # requests re-routed off a crash
    offload_retries: int = 0        # OFFLOAD-verdict/timeout re-routes
    duplicates: int = 0             # straggler completions deduplicated
    dropped_offloads: int = 0       # handoffs the adversary swallowed
    heartbeat_misses: int = 0       # step rounds stragglers sat out
    evacuated: int = 0              # requests stripped out of crashes

    @property
    def accounted(self) -> int:
        """Distinct rids that ended served or verdicted."""
        return len({r.rid for r in self.results}) \
            + len({r.req.rid for r in self.rejects})


class ClusterSupervisor:
    """Drives a cluster of ``EparaServingEngine``s under the control
    plane, with the recovery loop described in the module docstring.
    Implements ``core/faults.py``'s ``FaultTarget``."""

    def __init__(self, cp, engines: Dict[int, Any], *,
                 retry: Optional[RetryPolicy] = None,
                 injector: Optional[FaultInjector] = None,
                 metrics=None, tracer=None):
        self.cp = cp
        self.engines = dict(engines)
        self.retry = retry or RetryPolicy()
        self.injector = injector
        self.metrics = metrics
        self.tracer = tracer
        self.ledger: Dict[int, TrackedRequest] = {}
        self.down: set = set()
        self.report = ClusterReport()
        self._straggle: Dict[int, Tuple[float, float]] = {}
        self._drop_budget: Dict[int, int] = {}
        self._round = 0
        if metrics is not None:
            self._m = {
                "failovers": metrics.counter(
                    "cluster_failovers_total",
                    "requests re-routed off a crashed server"),
                "retries": metrics.counter(
                    "cluster_offload_retries_total",
                    "offload handoffs retried after timeout or verdict"),
                "duplicates": metrics.counter(
                    "cluster_duplicate_results_total",
                    "straggler completions deduplicated by (rid, sample)"),
                "dropped": metrics.counter(
                    "cluster_dropped_offloads_total",
                    "offload handoffs lost in flight"),
                "misses": metrics.counter(
                    "cluster_heartbeat_misses_total",
                    "step rounds a straggling server sat out"),
                "down": metrics.gauge(
                    "cluster_servers_down",
                    "servers currently flagged failed"),
            }
        else:
            self._m = None

    # -- submission -----------------------------------------------------
    def submit(self, service: str, req: Any, at_server: int,
               now: float = 0.0) -> TrackedRequest:
        """Route one request through the handler and place it.  The
        supervisor tracks it until served-or-verdicted."""
        rec = TrackedRequest(req=req, service=service, origin=at_server)
        self.ledger[req.rid] = rec
        decision = self.cp.handle(self._core_req(rec, now), now=now,
                                  at_server=at_server)
        key = decision.outcome.value
        self.report.outcomes[key] = self.report.outcomes.get(key, 0) + 1
        dest = (decision.destination
                if decision.outcome == Outcome.OFFLOAD else at_server)
        if dest is None or dest in self.down \
                or service not in self.engines[dest].runtimes:
            dest = self._any_host(service, exclude=set())
        if dest is None:
            self._fail(rec, now, reason="no alive host")
        else:
            self._place(rec, dest, now)
        return rec

    def _core_req(self, rec: TrackedRequest, now: float) -> Request:
        """Control-plane view of a tracked request: tried servers ride
        the handler's loop-prevention ``path`` so re-routes exclude
        them."""
        return Request(rid=rec.req.rid, service=rec.service,
                       arrival_s=now,
                       deadline_s=rec.req.deadline_s or 1e9,
                       path=tuple(sorted(rec.tried)),
                       offload_count=0)

    def _any_host(self, service: str, exclude: set) -> Optional[int]:
        for sid, eng in self.engines.items():
            if sid in self.down or sid in exclude:
                continue
            if service in eng.runtimes:
                return sid
        return None

    def _place(self, rec: TrackedRequest, dest: int, now: float) -> None:
        rec.attempts += 1
        rec.tried.add(dest)
        rec.server = dest
        rec.timeout_at = now + self.retry.timeout_s(
            rec.attempts - 1, rec.req.deadline_s or 0.0, now)
        budget = self._drop_budget.get(dest, 0)
        if budget > 0:
            # the adversary swallows this handoff: the request is never
            # submitted — only the armed timeout can recover it
            self._drop_budget[dest] = budget - 1
            rec.dropped = True
            self.report.dropped_offloads += 1
            if self._m:
                self._m["dropped"].inc()
            return
        rec.dropped = False
        self.engines[dest].submit(rec.service, rec.req, now)

    # -- FaultTarget ----------------------------------------------------
    def crash(self, ev: FaultEvent, now: float) -> None:
        sid = ev.sid
        if sid in self.down:
            return
        self.down.add(sid)
        self.cp.fail_server(sid, now)
        evacuated: List[Any] = []
        for rt in self.engines[sid].runtimes.values():
            evacuated.extend(rt.evacuate(now))
        self.report.evacuated += len(evacuated)
        if self.tracer is not None:
            self.tracer.instant("cluster", f"server{sid}", "crash",
                                evacuated=len(evacuated))
        for req in evacuated:
            rec = self.ledger.get(req.rid)
            if rec is None or rec.done:
                continue
            self.report.failovers += 1
            if self._m:
                self._m["failovers"].inc()
            self._reroute(rec, now, reason="crash")
        # any ledger entry still pointed at the corpse (e.g. placed but
        # dropped before submission) retries through its timeout
        if self._m:
            self._m["down"].set(float(len(self.down)))

    def restart(self, ev: FaultEvent, now: float) -> None:
        if ev.sid not in self.down:
            return
        self.down.discard(ev.sid)
        self.cp.repair_server(ev.sid, now)
        if self.tracer is not None:
            self.tracer.instant("cluster", f"server{ev.sid}", "restart")
        if self._m:
            self._m["down"].set(float(len(self.down)))

    def straggle(self, ev: FaultEvent, now: float) -> None:
        self._straggle[ev.sid] = (now + ev.duration_s,
                                  max(1.0, ev.factor))

    def corrupt(self, ev: FaultEvent, now: float) -> None:
        self.cp.sync.corrupt(ev.sid, factor=ev.factor)

    def drop_offload(self, ev: FaultEvent, now: float) -> None:
        self._drop_budget[ev.sid] = \
            self._drop_budget.get(ev.sid, 0) + ev.count

    # -- recovery -------------------------------------------------------
    def _reroute(self, rec: TrackedRequest, now: float,
                 reason: str) -> None:
        """Find the next-best placement for an open request.  Attempt
        budget exhausted: FAILED only when its current avenue is dead
        (crashed host / swallowed handoff / nowhere left) — a healthy but
        slow host keeps running with the timeout disarmed."""
        avenue_dead = (rec.dropped or rec.server in self.down
                       or rec.server < 0)
        if rec.attempts >= self.retry.max_attempts:
            if avenue_dead:
                self._fail(rec, now, reason=f"retry budget exhausted "
                                            f"({reason})")
            else:
                rec.timeout_at = float("inf")
            return
        decision = self.cp.handle(self._core_req(rec, now), now=now,
                                  at_server=rec.origin
                                  if rec.origin not in self.down
                                  else next(iter(
                                      set(self.engines) - self.down),
                                      rec.origin))
        dest: Optional[int] = None
        if decision.outcome == Outcome.OFFLOAD:
            dest = decision.destination
        elif decision.outcome in (Outcome.LOCAL, Outcome.LOCAL_CROSS,
                                  Outcome.LOCAL_DEVICE):
            dest = rec.origin
        if dest is not None and (dest in self.down
                                 or rec.service not in
                                 self.engines[dest].runtimes):
            dest = None
        if dest is None:
            # handler has no scored candidate — fall back to any alive
            # host, preferring untried ones, but never double-submit to a
            # server that may still be running this rid
            exclude = set(rec.tried)
            if not avenue_dead:
                exclude.add(rec.server)
            dest = self._any_host(rec.service, exclude=exclude)
            if dest is None and avenue_dead:
                dest = self._any_host(rec.service,
                                      exclude={rec.server})
        if dest is None:
            if avenue_dead:
                self._fail(rec, now, reason=f"no alive host ({reason})")
            else:
                rec.timeout_at = float("inf")
            return
        if self.tracer is not None:
            self.tracer.instant("cluster", str(rec.req.rid), "failover",
                                to=dest, reason=reason,
                                attempt=rec.attempts)
        self._place(rec, dest, now)

    def _fail(self, rec: TrackedRequest, now: float, reason: str) -> None:
        rec.done = True
        rec.timeout_at = float("inf")
        rec.verdict = AdmissionReject(
            req=rec.req, verdict=Outcome.FAILED, now=now, reason=reason,
            attempts=rec.attempts)
        self.report.rejects.append(rec.verdict)
        key = Outcome.FAILED.value
        self.report.outcomes[key] = self.report.outcomes.get(key, 0) + 1

    def _record_reject(self, rec: TrackedRequest,
                       rj: AdmissionReject) -> None:
        rec.done = True
        rec.timeout_at = float("inf")
        rec.verdict = dataclasses.replace(rj, attempts=rec.attempts)
        self.report.rejects.append(rec.verdict)

    def _collect(self, sid: int, service: str, stats: Any,
                 now: float) -> None:
        for res in stats.results:
            rec = self.ledger.get(res.rid)
            if rec is None:
                self.report.results.append(res)
                continue
            if res.sample in rec.results:
                # the straggler ALSO finished it — first completion won
                self.report.duplicates += 1
                if self._m:
                    self._m["duplicates"].inc()
                continue
            rec.results[res.sample] = res
            self.report.results.append(res)
            if res.sample == 0:
                rec.done = True
                rec.timeout_at = float("inf")
        for rj in stats.rejected:
            rec = self.ledger.get(rj.req.rid)
            if rec is None or rec.done:
                continue
            if rj.verdict is Outcome.OFFLOAD:
                # routable, not dead: the handler picks the next peer
                self.report.offload_retries += 1
                if self._m:
                    self._m["retries"].inc()
                rec.dropped = True      # not running anywhere right now
                self._reroute(rec, now, reason="offload verdict")
            else:
                self._record_reject(rec, rj)

    # -- drive loop -----------------------------------------------------
    def step(self, now: float) -> bool:
        """One cluster round: fire due faults, step every alive engine,
        feed queue-time back to the handler state, run the sync round,
        and fire expired retry timeouts.  Returns True when any engine
        made progress."""
        self._round += 1
        if self.injector is not None:
            self.injector.drive(now, self)
        progress = False
        for sid, eng in self.engines.items():
            if sid in self.down:
                continue
            until_factor = self._straggle.get(sid)
            if until_factor is not None:
                until, factor = until_factor
                if now >= until:
                    del self._straggle[sid]
                elif self._round % int(factor) != 0:
                    # the straggler only gets every factor-th round
                    self.report.heartbeat_misses += 1
                    if self._m:
                        self._m["misses"].inc()
                    continue
            for name, rt in eng.runtimes.items():
                if not (rt.pending() or rt.in_flight()):
                    continue
                stats = rt.step(now=now, max_wait_s=0.0)
                self.cp.set_queue_time(sid, name, stats.queue_time_s)
                self._collect(sid, name, stats, now)
                if (stats.results or stats.admitted or stats.decode_steps
                        or stats.prefill_chunk_tokens or stats.rejected
                        or stats.verify_launches or stats.draft_steps):
                    progress = True
        self.cp.publish_all(now)
        self.cp.sync_step(now)
        for rec in list(self.ledger.values()):
            if rec.open and now >= rec.timeout_at:
                self.report.offload_retries += 1
                if self._m:
                    self._m["retries"].inc()
                self._reroute(rec, now, reason="timeout")
        return progress

    def open_requests(self) -> List[TrackedRequest]:
        return [r for r in self.ledger.values() if r.open]

    def run_until_idle(self, now: float = 0.0, dt: float = 1.0,
                       clock: Optional[Callable[[], float]] = None,
                       max_rounds: int = 100000) -> ClusterReport:
        """Drive until every tracked rid is served-or-verdicted.  With a
        ``clock`` the caller's wall time advances ``now``; otherwise a
        logical clock steps by ``dt`` and JUMPS over idle gaps to the
        next armed timeout or scheduled fault, so backoff waits cost
        rounds, not wall time."""
        stall = 0
        for _ in range(max_rounds):
            if not self.open_requests():
                break
            now = clock() if clock is not None else now + dt
            progress = self.step(now)
            if progress:
                stall = 0
                continue
            stall += 1
            if clock is None:
                horizon = [r.timeout_at for r in self.open_requests()
                           if r.timeout_at < float("inf")]
                if self.injector is not None \
                        and self.injector.next_at() < float("inf"):
                    horizon.append(self.injector.next_at())
                if horizon:
                    now = max(now, min(horizon))
                    stall = 0
                    progress = self.step(now)
                    if progress:
                        continue
            if stall >= 3:
                # nothing can move: engines idle, no timeout or fault
                # left to jump to — verdict the stranded remainder
                for rec in self.open_requests():
                    self._fail(rec, now, reason="stranded (no progress)")
        else:
            for rec in self.open_requests():
                self._fail(rec, now, reason="round budget exhausted")
        # drain faults scheduled past the last served request: a
        # crash/restart pair must leave the cluster healed even when the
        # burst finishes before the restart's timestamp
        if self.injector is not None:
            while self.injector.next_at() < float("inf"):
                now = max(now, self.injector.next_at())
                self.injector.drive(now, self)
                self.cp.publish_all(now)
                self.cp.sync_step(now)
        self.report.rounds = self._round
        return self.report
