"""Cache pytree utilities for the serving engine.

Model caches are pytrees whose array leaves have layout (layers, batch, ...)
with ``len`` scalars.  These helpers slice/merge along the batch axis so the
engine can admit/evict slots without knowing each family's cache layout.
"""
from __future__ import annotations

from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp

_SCALAR_KEYS = ("len",)


def _is_scalar_entry(key: str) -> bool:
    return key in _SCALAR_KEYS


def map_batch(cache: Dict[str, Any], fn) -> Dict[str, Any]:
    """Apply fn to every array leaf along its batch axis (axis=1)."""
    out = {}
    for k, v in cache.items():
        out[k] = v if _is_scalar_entry(k) else fn(v)
    return out


def select_slots(cache: Dict[str, Any], idx: Sequence[int]) -> Dict[str, Any]:
    idx = jnp.asarray(idx)
    return map_batch(cache, lambda a: jnp.take(a, idx, axis=1))


def batch_size(cache: Dict[str, Any]) -> int:
    for k, v in cache.items():
        if not _is_scalar_entry(k):
            return v.shape[1]
    raise ValueError("cache has no array leaves")


def concat(caches: Sequence[Dict[str, Any]]) -> Dict[str, Any]:
    keys = caches[0].keys()
    out = {}
    for k in keys:
        if _is_scalar_entry(k):
            out[k] = caches[0][k]
        else:
            out[k] = jnp.concatenate([c[k] for c in caches], axis=1)
    return out


def cache_bytes(cache: Dict[str, Any]) -> int:
    return sum(v.size * v.dtype.itemsize for k, v in cache.items()
               if not _is_scalar_entry(k))
