"""Cache pytree utilities for the serving engine's slot data plane.

Model caches are arbitrary pytrees (flat dicts today — KV for attention
families, conv/ssd state for SSM/hybrid, encoder memory for enc-dec — but
nesting is allowed).  The slot engine admits and evicts requests without
knowing each family's layout; it relies only on a shape convention shared
by every family:

* ``ndim >= 2`` leaves are batched state with layout ``(layers, batch,
  ...)`` — the batch axis is axis 1;
* ``ndim == 1`` leaves are **per-slot** counters, batch axis 0 (the slot
  engine stores each slot's own sequence length here);
* ``ndim == 0`` leaves are counters shared by the whole batch (what the
  model ``prefill`` functions emit as ``len``).

``select_slots``/``concat`` slice and join along the batch axis (evict /
admit).  ``merge`` is the admission workhorse: it promotes shared ``len``
scalars to per-slot vectors, zero-pads differing trailing axes (ragged KV
sequence capacity) up to the max, and concatenates — so a freshly
prefilled single-request cache can join a live in-flight batch whose KV
capacity differs.  End-padding is safe for full-attention caches because
per-slot lengths mask the tail; ring (sliding-window) caches are never
padded in practice since every cache of the family shares ``S = window``.
"""
from __future__ import annotations

from typing import Any, List, Sequence, Union

import jax
import jax.numpy as jnp

Cache = Any  # pytree of arrays


def _batch_axis(leaf) -> Union[int, None]:
    """Batch axis of one leaf under the shape convention (None = shared)."""
    if leaf.ndim == 0:
        return None
    return 0 if leaf.ndim == 1 else 1


def map_batch(cache: Cache, fn) -> Cache:
    """Apply ``fn(leaf, batch_axis)`` to every batched leaf; shared scalars
    pass through untouched."""
    return jax.tree.map(
        lambda a: a if a.ndim == 0 else fn(a, _batch_axis(a)), cache)


def batch_size(cache: Cache) -> int:
    """Number of slots in the cache (size of the batch axis)."""
    for leaf in jax.tree.leaves(cache):
        if leaf.ndim >= 2:
            return int(leaf.shape[1])
    for leaf in jax.tree.leaves(cache):
        if leaf.ndim == 1:
            return int(leaf.shape[0])
    raise ValueError("cache has no batched leaves")


def select_slots(cache: Cache, idx: Sequence[int]) -> Cache:
    """Keep only the slots in ``idx`` (evict everything else)."""
    idx = jnp.asarray(idx, jnp.int32)
    return map_batch(cache, lambda a, ax: jnp.take(a, idx, axis=ax))


def concat(caches: Sequence[Cache]) -> Cache:
    """Join caches along the batch axis.  Leaf shapes must already agree
    away from the batch axis (use ``merge`` for ragged capacities); shared
    scalar leaves keep the first cache's value."""
    def join(*leaves):
        if leaves[0].ndim == 0:
            return leaves[0]
        return jnp.concatenate(leaves, axis=_batch_axis(leaves[0]))
    return jax.tree.map(join, *caches)


def lens(cache: Cache) -> jnp.ndarray:
    """Per-slot sequence lengths (B,) — broadcasts a shared scalar ``len``."""
    B = batch_size(cache)
    for leaf in jax.tree.leaves(cache):
        if leaf.ndim == 1:
            return leaf.astype(jnp.int32)
    for leaf in jax.tree.leaves(cache):
        if leaf.ndim == 0:
            return jnp.full((B,), leaf, jnp.int32)
    raise ValueError("cache has no length leaves")


def with_lens(cache: Cache, new_lens) -> Cache:
    """Replace every length leaf (ndim 0 or 1) with per-slot ``new_lens``.

    This is how the engine converts a model-emitted cache (shared scalar
    ``len``) into slot form before merging it into the live batch."""
    new_lens = jnp.asarray(new_lens, jnp.int32)
    if new_lens.ndim == 0:
        new_lens = new_lens[None]
    return jax.tree.map(
        lambda a: new_lens if a.ndim <= 1 and jnp.issubdtype(
            a.dtype, jnp.integer) else a, cache)


def pad_to(cache: Cache, like: Cache) -> Cache:
    """Zero-pad each batched leaf's trailing axes (everything after the
    batch axis) up to ``like``'s sizes.  ``like`` may be a cache or a
    pytree of shape tuples.  Used to grow a live batch's KV capacity when
    an admitted request needs a longer sequence budget."""
    leaves, treedef = jax.tree.flatten(cache)
    targets = [tuple(s.shape) if hasattr(s, "shape") else tuple(s)
               for s in jax.tree.leaves(
                   like, is_leaf=lambda x: isinstance(x, tuple))]
    if len(targets) != len(leaves):
        raise ValueError("pad_to: reference does not match cache structure")

    def pad_entry(leaf, target):
        if leaf.ndim <= 1:
            return leaf          # per-slot / shared counters never pad
        widths = []
        for d, (have, want) in enumerate(zip(leaf.shape, target)):
            if d == 1:           # batch axis: concat's job, never padded
                widths.append((0, 0))
                continue
            if want < have:
                raise ValueError(
                    f"pad_to cannot shrink axis {d}: {have} -> {want}")
            widths.append((0, want - have))
        if all(w == (0, 0) for w in widths):
            return leaf
        return jnp.pad(leaf, widths)

    return jax.tree.unflatten(
        treedef, [pad_entry(l, t) for l, t in zip(leaves, targets)])


def merge(caches: Sequence[Cache]) -> Cache:
    """Admission merge: per-slot length promotion + ragged-capacity padding
    + batch concat, in one call.

    Every input keeps its own sequence length; trailing axes that differ
    across inputs (KV capacity S) are zero-padded at the end to the max.
    The result always carries per-slot (B,) lengths, ready for the fused
    per-slot decode step."""
    caches = list(caches)
    if len(caches) == 1:
        c = caches[0]
        return with_lens(c, lens(c))
    normalized: List[Cache] = [with_lens(c, lens(c)) for c in caches]
    leaves_list = [jax.tree.leaves(c) for c in normalized]
    targets = []
    for position, leaf in enumerate(leaves_list[0]):
        if leaf.ndim <= 1:
            targets.append(tuple(leaf.shape))
            continue
        shape = list(leaf.shape)
        for other in leaves_list[1:]:
            o = other[position]
            if o.ndim != leaf.ndim:
                raise ValueError("merge: mismatched cache structures")
            for d in range(leaf.ndim):
                if d != 1:       # batch axis may differ freely
                    shape[d] = max(shape[d], o.shape[d])
        targets.append(tuple(shape))
    treedef = jax.tree.structure(normalized[0])
    target_tree = jax.tree.unflatten(treedef, targets)
    padded = [pad_to(c, target_tree) for c in normalized]
    return concat(padded)


def cache_bytes(cache: Cache) -> int:
    """Bytes held by the batched state (length counters are negligible and
    excluded, matching the allocator's VRAM accounting)."""
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(cache) if leaf.ndim >= 2)
