"""Telemetry -> simulator calibration: close the measure -> model ->
place loop.

The placement layer prices services with the event-driven simulator
(``repro/simulator/engine.py``), whose ``SimConfig`` carries behavioral
knobs the live data plane actually measures every step: the speculative
acceptance rate, per-service prefix-cache hit rates, and the per-token
prefill cost.  Before this module those knobs were hand-tuned (or
derived a priori from the workload generator); now a recorded serve —
``StepStats`` aggregates, a metrics snapshot, or live runtimes — folds
back into a calibrated ``SimConfig``, so the simulator the placement
layer prices against reflects what the deployment just did.

Derivations (each documented against the SimConfig field it feeds):

* ``spec_accept_rate`` — the sim commits ``1 + rate*k`` tokens per
  fused verify launch, so the measured rate is
  ``(accepted_tokens / verify_launches - 1) / k``, aggregated across
  speculating services weighted by their launch counts and clamped to
  [0, 1].
* ``prefix_hit_rates[service]`` — cached prompt tokens over total
  prompt tokens: ``hit_tokens / (hit_tokens + prefill_tokens_computed)``
  (the exact quantity the sim's hit-rate discount multiplies).
* ``prefill_token_s`` — measured prefill wall seconds per computed
  prompt token, when the run recorded both (else the base value
  stands).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterable, Mapping, Optional

from repro.simulator.engine import SimConfig

from .metrics import step_stat_sums


@dataclasses.dataclass
class ServiceTelemetry:
    """One service's calibration-relevant aggregates over a run."""
    service: str
    spec_k: int = 0                  # draft depth the service ran with
    accepted_tokens: int = 0         # target tokens committed by verify
    verify_launches: int = 0
    prefix_hit_tokens: int = 0       # prompt tokens served from cache
    prefill_tokens_computed: int = 0  # prompt tokens run through compute
    prefill_seconds: float = 0.0     # per-request prefill wall seconds
    decode_steps: int = 0

    @property
    def spec_accept_rate(self) -> Optional[float]:
        """Measured acceptance rate, in the sim's ``1 + rate*k`` frame;
        None when the service never speculated."""
        if self.spec_k <= 0 or self.verify_launches <= 0:
            return None
        per_launch = self.accepted_tokens / self.verify_launches
        return min(1.0, max(0.0, (per_launch - 1.0) / self.spec_k))

    @property
    def prefix_hit_rate(self) -> Optional[float]:
        """Measured cached-prompt-token fraction; None when the run saw
        no prompt tokens at all."""
        total = self.prefix_hit_tokens + self.prefill_tokens_computed
        if total <= 0:
            return None
        return self.prefix_hit_tokens / total

    @property
    def prefill_token_s(self) -> Optional[float]:
        if self.prefill_tokens_computed <= 0 or self.prefill_seconds <= 0:
            return None
        return self.prefill_seconds / self.prefill_tokens_computed


def telemetry_from_steps(service: str, steps: Iterable,
                         spec_k: int = 0) -> ServiceTelemetry:
    """Build telemetry from recorded ``StepStats`` — the same
    ``step_stat_sums`` fold the metrics registry and the benchmark
    aggregator run, so "measured aggregates" means one thing
    everywhere.  ``prefill_tokens_computed`` counts chunked prefill
    tokens net of cache hits (hit tokens never enter the (b2) budget);
    prefill seconds come from the finished results' own timings."""
    sums: Dict[str, float] = {}
    prefill_s = 0.0
    for st in steps:
        step_stat_sums(st, into=sums)
        prefill_s += sum(r.prefill_s for r in st.results)
    return ServiceTelemetry(
        service=service, spec_k=int(spec_k),
        accepted_tokens=int(sums.get("accepted_tokens", 0)),
        verify_launches=int(sums.get("verify_launches", 0)),
        prefix_hit_tokens=int(sums.get("prefix_hit_tokens", 0)),
        prefill_tokens_computed=int(sums.get("prefill_chunk_tokens", 0)),
        prefill_seconds=prefill_s,
        decode_steps=int(sums.get("decode_steps", 0)))


def telemetry_from_runtime(service: str, runtime) -> ServiceTelemetry:
    """Build telemetry straight off a live ``ServiceRuntime``'s
    cumulative counters (the launcher's path: exact, no sampling)."""
    return ServiceTelemetry(
        service=service, spec_k=runtime.speculate_k,
        accepted_tokens=runtime.accepted_tokens,
        verify_launches=runtime.verify_launches,
        prefix_hit_tokens=runtime.prefix_hit_tokens,
        prefill_tokens_computed=runtime.prefill_tokens_computed,
        prefill_seconds=runtime.prefill_seconds,
        decode_steps=runtime.decode_steps)


def merge_telemetry(items: Iterable[ServiceTelemetry]
                    ) -> Dict[str, ServiceTelemetry]:
    """Sum telemetry records by service name — a cluster run hosts the
    same service on several runtimes (one per server), and the measured
    counters are additive.  ``spec_k`` must agree across replicas (it is
    a plan knob, not a counter); a mismatch raises rather than averaging
    incomparable acceptance frames."""
    out: Dict[str, ServiceTelemetry] = {}
    for t in items:
        prev = out.get(t.service)
        if prev is None:
            out[t.service] = dataclasses.replace(t)
            continue
        if prev.spec_k != t.spec_k:
            raise ValueError(
                f"service {t.service!r} replicas disagree on spec_k "
                f"({prev.spec_k} vs {t.spec_k}); cannot merge acceptance "
                "telemetry across different draft depths")
        prev.accepted_tokens += t.accepted_tokens
        prev.verify_launches += t.verify_launches
        prev.prefix_hit_tokens += t.prefix_hit_tokens
        prev.prefill_tokens_computed += t.prefill_tokens_computed
        prev.prefill_seconds += t.prefill_seconds
        prev.decode_steps += t.decode_steps
    return out


def telemetry_from_snapshot(snapshot: Mapping[str, Any]
                            ) -> Dict[str, ServiceTelemetry]:
    """Rebuild per-service telemetry from a ``MetricsRegistry``
    snapshot (the JSONL record) — the offline path: a metrics file from
    a past run calibrates without re-running anything."""
    def series(name: str) -> Dict[str, float]:
        m = snapshot.get("metrics", {}).get(f"epara_{name}")
        out: Dict[str, float] = {}
        if not m:
            return out
        for row in m.get("values", []):
            svc = row.get("labels", {}).get("service", "")
            out[svc] = row.get("value", row.get("sum", 0.0))
        return out

    accepted = series("step_accepted_tokens_total")
    launches = series("step_verify_launches_total")
    hit_tokens = series("step_prefix_hit_tokens_total")
    computed = series("prefill_tokens_computed")
    spec_k = series("spec_k")
    prefill_s = series("prefill_seconds_total")
    steps = series("step_decode_steps_total")
    names = (set(accepted) | set(launches) | set(hit_tokens)
             | set(computed) | set(spec_k))
    return {svc: ServiceTelemetry(
        service=svc, spec_k=int(spec_k.get(svc, 0)),
        accepted_tokens=int(accepted.get(svc, 0)),
        verify_launches=int(launches.get(svc, 0)),
        prefix_hit_tokens=int(hit_tokens.get(svc, 0)),
        prefill_tokens_computed=int(computed.get(svc, 0)),
        prefill_seconds=prefill_s.get(svc, 0.0),
        decode_steps=int(steps.get(svc, 0))) for svc in sorted(names)}


def calibrate(telemetry: Mapping[str, ServiceTelemetry],
              base: Optional[SimConfig] = None) -> SimConfig:
    """Fold measured telemetry into ``SimConfig`` overrides.  Fields a
    run did not measure keep the base value — a cold run (no
    speculation, no prompts) calibrates to exactly the base config, so
    the loop is safe to run unconditionally."""
    base = SimConfig() if base is None else base
    over: Dict[str, Any] = {}
    # spec_accept_rate is a single scalar: launch-weighted mean across
    # the services that actually speculated
    num = den = 0.0
    for t in telemetry.values():
        r = t.spec_accept_rate
        if r is not None:
            num += r * t.verify_launches
            den += t.verify_launches
    if den > 0:
        over["spec_accept_rate"] = num / den
    rates = {t.service: t.prefix_hit_rate for t in telemetry.values()
             if t.prefix_hit_rate is not None}
    if rates:
        merged = dict(base.prefix_hit_rates or {})
        merged.update(rates)
        over["prefix_hit_rates"] = merged
    # prefill cost: token-weighted mean across services that timed it
    pnum = pden = 0.0
    for t in telemetry.values():
        s = t.prefill_token_s
        if s is not None:
            pnum += s * t.prefill_tokens_computed
            pden += t.prefill_tokens_computed
    if pden > 0:
        over["prefill_token_s"] = pnum / pden
    return dataclasses.replace(base, **over) if over else base


def calibration_report(telemetry: Mapping[str, ServiceTelemetry],
                       cfg: SimConfig) -> Dict[str, Any]:
    """The JSON document ``--calibrate-out`` writes: the derived
    ``SimConfig`` overrides plus per-service provenance, so the next
    session can audit WHERE each number came from."""
    return {
        "sim_config_overrides": {
            "spec_accept_rate": cfg.spec_accept_rate,
            "prefix_hit_rates": dict(cfg.prefix_hit_rates or {}),
            "prefill_token_s": cfg.prefill_token_s,
        },
        "telemetry": {
            name: {
                "spec_k": t.spec_k,
                "accepted_tokens": t.accepted_tokens,
                "verify_launches": t.verify_launches,
                "spec_accept_rate": t.spec_accept_rate,
                "prefix_hit_tokens": t.prefix_hit_tokens,
                "prefill_tokens_computed": t.prefill_tokens_computed,
                "prefix_hit_rate": t.prefix_hit_rate,
                "prefill_token_s": t.prefill_token_s,
                "decode_steps": t.decode_steps,
            } for name, t in sorted(telemetry.items())
        },
    }


def write_calibration(path: str,
                      telemetry: Mapping[str, ServiceTelemetry],
                      base: Optional[SimConfig] = None) -> SimConfig:
    cfg = calibrate(telemetry, base)
    with open(path, "w") as f:
        json.dump(calibration_report(telemetry, cfg), f, indent=2)
    return cfg
