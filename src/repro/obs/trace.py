"""Span-based request-lifecycle + engine-phase tracer.

Zero-dependency, host-side only: the tracer never touches a jax array or
a compiled function, so enabling it cannot change emitted tokens or
compile counts — it wall-clocks and annotates what the engine already
does.  Two kinds of timelines share one bounded ring buffer:

* **per-request lifecycle** — one logical thread per request id
  (``tid=str(rid)``; n>1 sampling forks get ``"rid.sample"``), with a
  properly nested span stack::

      request                      submit -> finish/verdict
        queued                     submit -> admission (or verdict)
        prefill                    admission -> first token
          prefill_chunk ...        one complete event per (b2) chunk
        decode                     first token -> done
          spec_round ...           one complete event per verify round
        parked                     preemption park -> resume
        decode                     resume -> done (re-opened)

* **per-step engine phases** — complete events on ``tid="engine"``
  (``step`` / ``evict`` / ``admit`` / ``preempt`` / ``chunk`` /
  ``fused_decode`` / ``verify`` / ``sample``), so a Perfetto track shows
  where each scheduling round's wall time went.

The ring buffer (``capacity`` finished events; oldest dropped, counted
in ``dropped``) bounds memory on long serves.  ``chrome_trace()``
exports the Chrome trace-event JSON (``ph``/``ts``/``dur``/``pid``/
``tid`` complete+instant+metadata events) that Perfetto/chrome://tracing
load directly; ``span_tree()`` rebuilds the nested span forest of one
timeline for programmatic checks (the tests' balance/monotonicity
invariants).

A module-level ``NULL_TRACER`` no-ops every method with ``enabled =
False`` — the engine holds it by default so the disabled layer costs one
predicate per call site and allocates nothing.
"""
from __future__ import annotations

import dataclasses
import json
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

# event record layout (tuples, not dicts: the ring buffer holds many)
_COMPLETE, _INSTANT = "X", "i"


@dataclasses.dataclass
class Span:
    """One reconstructed span of a timeline's tree (``span_tree``)."""
    name: str
    start: float                 # tracer-clock seconds
    end: float
    args: Dict[str, Any]
    children: List["Span"] = dataclasses.field(default_factory=list)

    @property
    def dur(self) -> float:
        return self.end - self.start


class _NullTracer:
    """The disabled layer: every method is a no-op, ``enabled`` is
    False so call sites can skip building args entirely."""
    enabled = False

    def begin(self, *a, **k):
        pass

    def end(self, *a, **k):
        pass

    def complete(self, *a, **k):
        pass

    def instant(self, *a, **k):
        pass

    def close(self, *a, **k):
        pass

    def clock(self) -> float:
        return 0.0


NULL_TRACER = _NullTracer()


class Tracer:
    """Bounded-ring span recorder with Chrome trace-event export."""

    enabled = True

    def __init__(self, capacity: int = 65536,
                 clock: Optional[Callable[[], float]] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = capacity
        self.clock = time.perf_counter if clock is None else clock
        self._events: deque = deque(maxlen=capacity)
        self._stacks: Dict[Tuple[str, str], List] = {}
        self.dropped = 0
        self.emitted = 0

    # -- recording ------------------------------------------------------
    def _push(self, rec) -> None:
        if len(self._events) == self.capacity:
            self.dropped += 1
        self._events.append(rec)
        self.emitted += 1

    def begin(self, pid: str, tid: str, name: str,
              ts: Optional[float] = None, **args) -> None:
        """Open a nested span on the (pid, tid) timeline."""
        ts = self.clock() if ts is None else ts
        self._stacks.setdefault((pid, tid), []).append([name, ts, args])

    def end(self, pid: str, tid: str, ts: Optional[float] = None,
            **args) -> None:
        """Close the innermost open span of the timeline (no-op when
        nothing is open, so lifecycle teardown paths can close
        defensively)."""
        stack = self._stacks.get((pid, tid))
        if not stack:
            return
        ts = self.clock() if ts is None else ts
        name, t0, a0 = stack.pop()
        if args:
            a0 = {**a0, **args}
        self._push((_COMPLETE, pid, tid, name, t0, max(ts, t0), a0))
        if not stack:
            self._stacks.pop((pid, tid), None)

    def close(self, pid: str, tid: str, **args) -> None:
        """End EVERY open span of the timeline (innermost first) — the
        request-teardown hook that keeps trees balanced no matter which
        state (queued / prefill / decode / parked) the request dies in.
        Extra ``args`` (e.g. an admission verdict) land on the outermost
        span."""
        stack = self._stacks.get((pid, tid))
        while stack:
            self.end(pid, tid, **(args if len(stack) == 1 else {}))
            stack = self._stacks.get((pid, tid))

    def complete(self, pid: str, tid: str, name: str, start: float,
                 end: Optional[float] = None, **args) -> None:
        """Record an already-timed span (phase timings, chunk calls)."""
        end = self.clock() if end is None else end
        self._push((_COMPLETE, pid, tid, name, start, max(end, start),
                    args))

    def instant(self, pid: str, tid: str, name: str,
                ts: Optional[float] = None, **args) -> None:
        ts = self.clock() if ts is None else ts
        self._push((_INSTANT, pid, tid, name, ts, ts, args))

    # -- introspection / export ----------------------------------------
    def open_spans(self, pid: str, tid: str) -> List[str]:
        return [e[0] for e in self._stacks.get((pid, tid), [])]

    def events(self) -> List[Tuple]:
        return list(self._events)

    def timelines(self) -> List[Tuple[str, str]]:
        seen: Dict[Tuple[str, str], None] = {}
        for rec in self._events:
            seen.setdefault((rec[1], rec[2]))
        return list(seen)

    def span_tree(self, pid: str, tid: str
                  ) -> Tuple[List[Span], List[Span]]:
        """Rebuild one timeline's nested span forest from its finished
        complete events.  Returns ``(roots, instants)``; instants are
        zero-duration leaves reported separately.  Reconstruction is the
        standard interval-stack replay — valid because the recording API
        only ever closes the innermost span, so finished events of one
        timeline are properly nested by construction."""
        spans = []
        instants = []
        for rec in self._events:
            kind, p, t, name, t0, t1, args = rec
            if (p, t) != (pid, tid):
                continue
            if kind == _INSTANT:
                instants.append(Span(name, t0, t1, dict(args)))
            else:
                spans.append(Span(name, t0, t1, dict(args)))
        # sort outer-first: by start asc, then end desc (parent before
        # child when they share a start timestamp)
        spans.sort(key=lambda s: (s.start, -s.end))
        roots: List[Span] = []
        stack: List[Span] = []
        for s in spans:
            while stack and s.start >= stack[-1].end:
                stack.pop()
            if stack:
                stack[-1].children.append(s)
            else:
                roots.append(s)
            stack.append(s)
        return roots, instants

    def chrome_trace(self) -> Dict[str, Any]:
        """The Chrome trace-event JSON dict (load in Perfetto or
        chrome://tracing).  pids/tids are dense ints with
        ``process_name`` / ``thread_name`` metadata events carrying the
        service / request names; ``ts``/``dur`` are microseconds."""
        pids: Dict[str, int] = {}
        tids: Dict[Tuple[str, str], int] = {}
        out: List[Dict[str, Any]] = []
        for rec in self._events:
            kind, p, t, name, t0, t1, args = rec
            pid = pids.setdefault(p, len(pids) + 1)
            tid = tids.setdefault((p, t), len(tids) + 1)
            ev: Dict[str, Any] = {
                "name": name, "cat": "obs", "ph": kind, "pid": pid,
                "tid": tid, "ts": round(t0 * 1e6, 3)}
            if kind == _COMPLETE:
                ev["dur"] = round((t1 - t0) * 1e6, 3)
            else:
                ev["s"] = "t"
            if args:
                ev["args"] = dict(args)
            out.append(ev)
        meta: List[Dict[str, Any]] = []
        for p, pid in pids.items():
            meta.append({"name": "process_name", "ph": "M", "pid": pid,
                         "tid": 0, "args": {"name": p}})
        for (p, t), tid in tids.items():
            meta.append({"name": "thread_name", "ph": "M",
                         "pid": pids[p], "tid": tid, "args": {"name": t}})
        return {"traceEvents": meta + out,
                "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped,
                              "emitted_events": self.emitted}}

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)


def validate_chrome_trace(doc: Any) -> int:
    """Structural check of an exported trace document: well-formed
    ``traceEvents`` with the mandatory ``ph``/``ts``/``pid`` fields
    (``dur`` on complete events).  Returns the event count; raises
    ``ValueError`` on the first malformed event — the CI smoke gate."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("trace document must carry a traceEvents list")
    events = doc["traceEvents"]
    if not isinstance(events, list) or not events:
        raise ValueError("traceEvents must be a non-empty list")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        if ev.get("ph") == "M":
            if "name" not in ev or "pid" not in ev:
                raise ValueError(f"metadata event {i} lacks name/pid")
            continue
        for field in ("name", "ph", "ts", "pid", "tid"):
            if field not in ev:
                raise ValueError(f"event {i} lacks {field!r}: {ev}")
        if ev["ph"] == "X" and "dur" not in ev:
            raise ValueError(f"complete event {i} lacks dur: {ev}")
        if ev["ph"] == "X" and ev["dur"] < 0:
            raise ValueError(f"event {i} has negative dur: {ev}")
    return len(events)
