"""Observability layer: request-lifecycle tracing, metrics exposition,
and telemetry-calibrated simulation.

Three zero-dependency, host-side-only modules (enabling any of them
cannot change emitted tokens or compile counts — asserted by
``tests/test_obs.py``):

* ``obs.trace`` — span tracer with a bounded ring buffer and
  Chrome-trace-event JSON export (Perfetto-loadable).
* ``obs.metrics`` — counter/gauge/histogram registry with Prometheus
  text exposition and JSONL snapshots, fed per step by the engine.
* ``obs.calibrate`` — folds recorded telemetry back into ``SimConfig``
  overrides (``spec_accept_rate``, ``prefix_hit_rates``,
  ``prefill_token_s``) so placement prices against measured behavior.

Wiring: pass ``tracer=``/``metrics=`` to ``ServiceRuntime`` (the
launchers' ``--trace-out`` / ``--metrics-out`` / ``--calibrate-out``
flags do this for every deployed service).  Default is off:
``NULL_TRACER`` and no registry, byte-inert.
"""
from .calibrate import (ServiceTelemetry, calibrate, calibration_report,
                        merge_telemetry, telemetry_from_runtime,
                        telemetry_from_snapshot, telemetry_from_steps,
                        write_calibration)
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      parse_prometheus_text, step_stat_sums)
from .trace import NULL_TRACER, Span, Tracer, validate_chrome_trace

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "NULL_TRACER",
    "ServiceTelemetry", "Span", "Tracer", "calibrate",
    "calibration_report", "merge_telemetry", "parse_prometheus_text",
    "step_stat_sums", "telemetry_from_runtime", "telemetry_from_snapshot",
    "telemetry_from_steps", "validate_chrome_trace", "write_calibration",
]
