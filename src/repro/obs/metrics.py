"""Counter / gauge / histogram registry with Prometheus text exposition
and JSONL snapshots.

Zero-dependency and host-side only (like ``obs/trace.py``): the registry
is fed numbers the engine already computes — ``StepStats`` counter
deltas, per-request timings at eviction, arena occupancy — so enabling
it cannot change tokens or compile counts.

``step_stat_sums`` is THE StepStats summing primitive: it folds every
numeric field of a ``StepStats`` (or any dataclass of counters) into an
accumulator dict.  The benchmark aggregator (``benchmarks/common.py``)
and the registry's ``observe_step`` both call it, so "sum the step
telemetry" exists exactly once.

Exposition formats:

* ``prometheus_text()`` — the Prometheus text format (``# HELP`` /
  ``# TYPE`` / ``name{label="v"} value``; histograms with cumulative
  ``_bucket{le=...}`` + ``_sum`` + ``_count`` series).
* ``snapshot()`` / ``append_jsonl(path)`` — one JSON object per call
  with every series' current value, for offline analysis and the
  calibration loop (``obs/calibrate.py`` can rebuild service telemetry
  from a snapshot alone).
"""
from __future__ import annotations

import dataclasses
import json
import math
import time
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

# -- shared StepStats summing (the one copy of the fold) ----------------

# fields that are per-step LEVELS (not deltas): summing them across steps
# would double-count standing state, so the fold skips them
_LEVEL_FIELDS = frozenset({"now", "in_flight", "pending", "parked",
                           "queue_time_s", "spec_slots"})


def step_stat_sums(stats, into: Optional[Dict[str, float]] = None,
                   ) -> Dict[str, float]:
    """Fold one telemetry record's numeric delta fields into ``into``
    (list-valued fields like ``results``/``rejected`` and per-step level
    fields like ``in_flight`` are skipped).  Works on any dataclass of
    counters — ``StepStats`` today, without importing the serving engine
    (no circular dependency)."""
    acc = {} if into is None else into
    for f in dataclasses.fields(stats):
        if f.name in _LEVEL_FIELDS:
            continue
        v = getattr(stats, f.name)
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            continue
        acc[f.name] = acc.get(f.name, 0) + v
    return acc


# -- metric primitives --------------------------------------------------

DEFAULT_LATENCY_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025,
                           0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def _label_key(labels: Mapping[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted(labels.items()))


def _fmt_labels(key: Tuple[Tuple[str, str], ...],
                extra: Tuple[Tuple[str, str], ...] = ()) -> str:
    items = key + extra
    if not items:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in items)
    return "{" + body + "}"


def _fmt_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if isinstance(v, float) and v.is_integer():
        return str(int(v))
    return repr(v)


class Counter:
    """Monotonically increasing value per label set."""

    kind = "counter"

    def __init__(self, name: str, help: str):
        self.name, self.help = name, help
        self._values: Dict[Tuple, float] = {}

    def inc(self, v: float = 1.0, **labels) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name} cannot decrease by {v}")
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0.0) + v

    def value(self, **labels) -> float:
        return self._values.get(_label_key(labels), 0.0)

    def expose(self) -> List[str]:
        return [f"{self.name}{_fmt_labels(k)} {_fmt_value(v)}"
                for k, v in sorted(self._values.items())]

    def snapshot(self) -> Dict[str, Any]:
        return {"kind": self.kind,
                "values": [{"labels": dict(k), "value": v}
                           for k, v in sorted(self._values.items())]}


class Gauge(Counter):
    """Set-to-current value per label set (occupancy, queue depth)."""

    kind = "gauge"

    def set(self, v: float, **labels) -> None:
        self._values[_label_key(labels)] = float(v)

    def inc(self, v: float = 1.0, **labels) -> None:
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0.0) + v


class Histogram:
    """Fixed-bucket histogram: per label set, cumulative bucket counts
    (Prometheus ``le`` semantics: ``count(x <= le)``), plus sum/count."""

    kind = "histogram"

    def __init__(self, name: str, help: str,
                 buckets: Iterable[float] = DEFAULT_LATENCY_BUCKETS):
        self.name, self.help = name, help
        bs = sorted(set(float(b) for b in buckets))
        if not bs:
            raise ValueError(f"histogram {name} needs >= 1 finite bucket")
        if bs[-1] != math.inf:
            bs.append(math.inf)
        self.buckets = tuple(bs)
        self._counts: Dict[Tuple, List[int]] = {}
        self._sum: Dict[Tuple, float] = {}
        self._n: Dict[Tuple, int] = {}

    def observe(self, v: float, **labels) -> None:
        key = _label_key(labels)
        counts = self._counts.setdefault(key, [0] * len(self.buckets))
        for i, le in enumerate(self.buckets):
            if v <= le:
                counts[i] += 1
                break
        self._sum[key] = self._sum.get(key, 0.0) + v
        self._n[key] = self._n.get(key, 0) + 1

    def value(self, **labels) -> Dict[str, Any]:
        """Cumulative bucket counts + sum + count for one label set."""
        key = _label_key(labels)
        counts = self._counts.get(key, [0] * len(self.buckets))
        cum, acc = [], 0
        for c in counts:
            acc += c
            cum.append(acc)
        return {"buckets": dict(zip((_fmt_value(b) for b in self.buckets),
                                    cum)),
                "sum": self._sum.get(key, 0.0),
                "count": self._n.get(key, 0)}

    def expose(self) -> List[str]:
        out = []
        for key in sorted(self._counts):
            acc = 0
            for le, c in zip(self.buckets, self._counts[key]):
                acc += c
                out.append(f"{self.name}_bucket"
                           f"{_fmt_labels(key, (('le', _fmt_value(le)),))}"
                           f" {acc}")
            out.append(f"{self.name}_sum{_fmt_labels(key)} "
                       f"{_fmt_value(self._sum[key])}")
            out.append(f"{self.name}_count{_fmt_labels(key)} "
                       f"{self._n[key]}")
        return out

    def snapshot(self) -> Dict[str, Any]:
        return {"kind": self.kind,
                "values": [{"labels": dict(k), **self.value(**dict(k))}
                           for k in sorted(self._counts)]}


class MetricsRegistry:
    """Named metric registry + the serving-stack feed methods.

    The engine calls ``observe_step`` once per scheduling round and
    ``observe_request`` once per finished request; everything else
    (exposition, snapshots, calibration reads) is pull-based."""

    def __init__(self, namespace: str = "epara"):
        self.namespace = namespace
        self._metrics: Dict[str, Any] = {}

    # -- registration ---------------------------------------------------
    def _register(self, cls, name: str, help: str, **kw):
        full = f"{self.namespace}_{name}" if self.namespace else name
        m = self._metrics.get(full)
        if m is None:
            m = cls(full, help, **kw)
            self._metrics[full] = m
        elif not isinstance(m, cls):
            raise ValueError(f"metric {full} already registered as "
                             f"{m.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._register(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Iterable[float] = DEFAULT_LATENCY_BUCKETS
                  ) -> Histogram:
        return self._register(Histogram, name, help, buckets=buckets)

    # -- serving-stack feeds --------------------------------------------
    def observe_step(self, service: str, stats, runtime=None) -> None:
        """Fold one ``StepStats`` into the registry: every numeric delta
        field becomes a ``step_<field>_total`` counter (via the shared
        ``step_stat_sums`` fold — the same logic the benchmark
        aggregator runs), level fields become gauges, and the runtime
        (when given) contributes arena occupancy + compile counts +
        calibration inputs."""
        sums = step_stat_sums(stats)
        for field, v in sums.items():
            if v:
                self.counter(f"step_{field}_total",
                             f"sum of StepStats.{field} across steps"
                             ).inc(v, service=service)
        self.gauge("in_flight", "occupied decode slots").set(
            stats.in_flight, service=service)
        self.gauge("pending", "queued requests").set(
            stats.pending, service=service)
        self.gauge("parked", "preempted requests holding frozen blocks"
                   ).set(stats.parked, service=service)
        self.gauge("queue_time_estimate_seconds",
                   "engine's queue-wait estimate for a new arrival").set(
            stats.queue_time_s, service=service)
        self.counter("steps_total", "scheduling rounds").inc(
            1, service=service)
        if stats.results:
            self.counter("requests_finished_total",
                         "requests that completed decode").inc(
                len(stats.results), service=service)
            self.counter("tokens_generated_total",
                         "tokens emitted by finished requests").inc(
                sum(len(r.tokens) for r in stats.results),
                service=service)
            self.counter("prefill_seconds_total",
                         "per-request prefill wall seconds").inc(
                sum(r.prefill_s for r in stats.results), service=service)
        if runtime is not None:
            self.observe_runtime(service, runtime)

    def observe_runtime(self, service: str, runtime) -> None:
        """Gauges read straight off the runtime's cumulative state:
        arena block occupancy, compile counts, calibration inputs
        (``spec_k`` so a snapshot alone can derive the acceptance
        rate)."""
        used = total = 0
        for g in runtime.groups.values():
            arena = g.arena
            if arena is None:
                continue
            total += arena.pool_blocks
            used += arena.pool_blocks - arena.free_capacity
        if total:
            self.gauge("arena_blocks_used", "allocated arena blocks"
                       ).set(used, service=service)
            self.gauge("arena_block_occupancy_ratio",
                       "allocated / pool blocks").set(
                used / total, service=service)
        self.gauge("decode_compiles", "fused decode step traces").set(
            runtime.decode_traces, service=service)
        self.gauge("prefill_compiles", "prefill/chunk traces").set(
            runtime.prefill_traces, service=service)
        self.gauge("prefill_tokens_computed",
                   "prompt tokens run through prefill compute").set(
            runtime.prefill_tokens_computed, service=service)
        self.gauge("spec_k", "speculative draft depth (0 = off)").set(
            runtime.speculate_k, service=service)

    def observe_request(self, service: str, *, ttft_s: float,
                        tpot_s: Optional[float], queue_wait_s: float,
                        new_tokens: int) -> None:
        """Per-request latency decomposition, recorded at eviction."""
        self.histogram("ttft_seconds",
                       "admission -> first token").observe(
            max(0.0, ttft_s), service=service)
        if tpot_s is not None:
            self.histogram("tpot_seconds",
                           "per-token decode latency").observe(
                max(0.0, tpot_s), service=service)
        self.histogram("queue_wait_seconds",
                       "submit -> admission").observe(
            max(0.0, queue_wait_s), service=service)
        self.histogram(
            "request_tokens", "tokens generated per request",
            buckets=(1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)
        ).observe(new_tokens, service=service)

    # -- exposition -----------------------------------------------------
    def prometheus_text(self) -> str:
        lines: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            lines.extend(m.expose())
        return "\n".join(lines) + "\n"

    def write_prometheus(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.prometheus_text())

    def snapshot(self) -> Dict[str, Any]:
        return {"ts": time.time(),
                "metrics": {name: m.snapshot()
                            for name, m in sorted(self._metrics.items())}}

    def append_jsonl(self, path: str) -> None:
        with open(path, "a") as f:
            f.write(json.dumps(self.snapshot()) + "\n")


def parse_prometheus_text(text: str) -> Dict[str, float]:
    """Minimal parser of the Prometheus text format — the CI smoke gate
    and the tests' round-trip check.  Returns ``{series: value}`` keyed
    by ``name{labels}``; raises ``ValueError`` on any malformed line."""
    out: Dict[str, float] = {}
    for i, line in enumerate(text.splitlines()):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        try:
            series, value = line.rsplit(" ", 1)
        except ValueError:
            raise ValueError(f"line {i}: no value separator: {line!r}")
        if "{" in series and not series.endswith("}"):
            raise ValueError(f"line {i}: unbalanced labels: {line!r}")
        out[series] = math.inf if value == "+Inf" else float(value)
    if not out:
        raise ValueError("no samples in exposition")
    return out
