"""Data pipeline: deterministic synthetic token streams for training and
request generators for serving.

Synthetic text is a structured Markov-ish mixture (not uniform noise) so
training loss actually decreases and overfitting tests are meaningful:
each document draws a latent "topic" vector that biases a per-position
transition rule.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    n_topics: int = 16

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = min(self.vocab_size, 4096)  # active vocab subset
        self._active = v
        self._topic_bias = rng.integers(0, v, size=(self.n_topics, 8))
        self._step = 0

    def _sample_doc(self, rng: np.random.Generator) -> np.ndarray:
        v = self._active
        topic = rng.integers(0, self.n_topics)
        bias = self._topic_bias[topic]
        toks = np.empty(self.seq_len + 1, np.int32)
        toks[0] = rng.integers(0, v)
        for t in range(1, self.seq_len + 1):
            if rng.random() < 0.6:
                # deterministic-ish continuation: next token depends on
                # previous token and topic (learnable structure)
                toks[t] = (toks[t - 1] * 31 + bias[t % 8]) % v
            else:
                toks[t] = rng.integers(0, v)
        return toks

    def batch(self, step: Optional[int] = None) -> Dict[str, np.ndarray]:
        """Returns {"tokens": (B, L), "labels": (B, L)} — labels are the
        next-token shift."""
        step = self._step if step is None else step
        self._step = step + 1
        rng = np.random.default_rng((self.seed << 20) ^ step)
        docs = np.stack([self._sample_doc(rng)
                         for _ in range(self.batch_size)])
        return {"tokens": docs[:, :-1], "labels": docs[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch()


@dataclasses.dataclass
class RequestStream:
    """Poisson / Gamma request arrivals for serving tests (per-service).

    ``burstiness`` > 1 gives Gamma inter-arrivals with CV^2 = burstiness —
    the paper's 'abrupt or uneven' edge arrivals."""
    rate: float                 # requests / sec
    horizon_s: float
    seed: int = 0
    burstiness: float = 1.0

    def arrival_times(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        n_expect = max(4, int(self.rate * self.horizon_s * 2))
        if self.burstiness <= 1.0:
            gaps = rng.exponential(1.0 / self.rate, size=n_expect)
        else:
            shape = 1.0 / self.burstiness
            scale = 1.0 / (self.rate * shape)
            gaps = rng.gamma(shape, scale, size=n_expect)
        times = np.cumsum(gaps)
        return times[times < self.horizon_s]
