PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test smoke bench bench-paged bench-chunked bench-prefix \
	bench-decode bench-spec bench-goodput bench-chaos serve obs-smoke \
	chaos-smoke quickstart

test:                ## tier-1 suite
	python -m pytest -x -q

smoke:               ## tiny-config benchmark pass (continuous batching)
	python -m benchmarks.run --smoke

bench:               ## full benchmark suite (paper figures)
	python -m benchmarks.run

bench-paged:         ## paged KV arena vs dense merge vs sync data planes
	REPRO_BENCH_SMOKE=$${REPRO_BENCH_SMOKE:-0} PYTHONHASHSEED=0 \
	REPRO_BENCH_SECTION=live,sim python -m benchmarks.continuous_batching

bench-chunked:       ## chunked vs unchunked prefill (head-of-line stall)
	REPRO_BENCH_SMOKE=$${REPRO_BENCH_SMOKE:-0} PYTHONHASHSEED=0 \
	REPRO_BENCH_SECTION=chunked python -m benchmarks.continuous_batching

bench-prefix:        ## radix prefix cache vs cold prefill (token reuse)
	REPRO_BENCH_SMOKE=$${REPRO_BENCH_SMOKE:-0} PYTHONHASHSEED=0 \
	REPRO_BENCH_SECTION=prefix python -m benchmarks.continuous_batching

bench-decode:        ## zero-gather paged decode vs dense-gather oracle
	REPRO_BENCH_SMOKE=$${REPRO_BENCH_SMOKE:-0} PYTHONHASHSEED=0 \
	REPRO_BENCH_SECTION=decode python -m benchmarks.continuous_batching

bench-spec:          ## speculative decode vs oracle (accepted/launch gate)
	REPRO_BENCH_SMOKE=$${REPRO_BENCH_SMOKE:-0} PYTHONHASHSEED=0 \
	REPRO_BENCH_SECTION=spec python -m benchmarks.continuous_batching

bench-goodput:       ## sdf admission + parking preemption vs fifo
	REPRO_BENCH_SMOKE=$${REPRO_BENCH_SMOKE:-0} PYTHONHASHSEED=0 \
	REPRO_BENCH_SECTION=goodput python -m benchmarks.continuous_batching

bench-chaos:         ## crash-mid-burst recovery vs failure-free oracle
	REPRO_BENCH_SMOKE=$${REPRO_BENCH_SMOKE:-0} PYTHONHASHSEED=0 \
	REPRO_BENCH_SECTION=chaos python -m benchmarks.continuous_batching

serve:               ## end-to-end serving driver
	python -m repro.launch.serve

chaos-smoke:         ## crash one server mid-burst; all rids must account
	python examples/serve_cluster.py --requests 9 --chaos

obs-smoke:           ## tiny traced+metered serve; validate the artifacts
	python -m repro.launch.serve --archs minicpm-2b --requests 6 \
		--max-new-tokens 4 --trace-out obs_trace.json \
		--metrics-out obs_metrics.prom \
		--calibrate-out obs_calibration.json
	python -c 'import json; from repro.obs import validate_chrome_trace, \
		parse_prometheus_text; \
		n = validate_chrome_trace(json.load(open("obs_trace.json"))); \
		m = parse_prometheus_text(open("obs_metrics.prom").read()); \
		c = json.load(open("obs_calibration.json")); \
		print("obs-smoke ok:", n, "trace events,", len(m), \
		      "series, overrides:", c["sim_config_overrides"])'

quickstart:
	python examples/quickstart.py
