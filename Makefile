PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test smoke bench serve quickstart

test:                ## tier-1 suite
	python -m pytest -x -q

smoke:               ## tiny-config benchmark pass (continuous batching)
	python -m benchmarks.run --smoke

bench:               ## full benchmark suite (paper figures)
	python -m benchmarks.run

serve:               ## end-to-end serving driver
	python -m repro.launch.serve

quickstart:
	python examples/quickstart.py
